import os
import sys

# Smoke tests and benches must see ONE device — the 512-device override is
# exclusively for launch/dryrun.py (per the multi-pod dry-run contract).
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
