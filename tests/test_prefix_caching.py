"""Refcounted prefix caching through the ServingCore.

Covers the allocator's sharing/commit/LRU semantics, the simulator's
suffix-only prefill charging (the TTFT win on shared-system-prompt traffic),
NaN-safe metrics, the shared-aware no-progress ``MemoryError`` accounting,
cross-backend equivalence of admission order and per-request hit decisions,
and the acceptance bar: real-engine greedy outputs are **bit-identical**
with caching on vs off.
"""
import math

import jax
import pytest

from repro.core.scheduler.policies import fcfs, oracle_sjf
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving import BlockAllocator, prefix_chunk_hashes
from repro.serving.metrics import report
from repro.serving.simulator import CostModel, simulate


def _cost():
    return CostModel(iter_base_s=0.01, per_seq_s=0.0,
                     prefill_per_token_s=0.001)


def _words(n, tag=""):
    return " ".join(f"{tag}w{j}" for j in range(n))


# ----------------------------------------------------------- allocator units
def test_committed_prefix_blocks_are_shared():
    a = BlockAllocator(total_blocks=16, block_size=16)
    hashes = prefix_chunk_hashes(list(range(64)), 16)          # 4 full chunks
    assert a.allocate(1, 80, hashes) == 0                      # cold miss
    assert a.used_blocks == 5
    a.commit(1)
    assert a.allocate(2, 80, hashes) == 4                      # share all 4
    assert a.used_blocks == 6                                  # 5 + 1 new
    assert a.reserved(1) == a.reserved(2) == 5
    a.free(1)
    assert a.used_blocks == 5                                  # still pinned
    a.free(2)
    assert a.used_blocks == 0
    assert a.cached_blocks == 4                                # parked in LRU
    assert a.free_blocks == 16                                 # and reusable


def test_uncommitted_prefixes_never_hit():
    a = BlockAllocator(total_blocks=16, block_size=16)
    hashes = prefix_chunk_hashes(list(range(32)), 16)
    a.allocate(1, 48, hashes)
    assert a.cached_prefix_blocks(hashes) == 0                 # mid-prefill
    assert a.allocate(2, 48, hashes) == 0                      # concurrent dup
    a.commit(1)
    assert a.cached_prefix_blocks(hashes) == 2
    # the duplicate's anonymous blocks recycle; the owner's park in the LRU
    a.free(2)
    assert a.cached_blocks == 0
    a.free(1)
    assert a.cached_blocks == 2


def test_lru_eviction_is_oldest_first_and_notifies():
    a = BlockAllocator(total_blocks=4, block_size=16)
    evicted = []
    a.add_evict_listener(evicted.append)
    h1 = prefix_chunk_hashes([1] * 16, 16)
    h2 = prefix_chunk_hashes([2] * 16, 16)
    a.allocate(1, 16, h1), a.commit(1), a.free(1)              # older content
    a.allocate(2, 16, h2), a.commit(2), a.free(2)              # newer content
    assert a.cached_blocks == 2 and a.free_blocks == 4
    a.allocate(3, 48)                  # 3 blocks: mint 2, evict exactly one
    assert evicted == h1                                       # oldest first
    assert a.cached_prefix_blocks(h2) == 1                     # newer survives
    a.free(3)
    a.allocate(4, 64)                                          # full pressure
    assert evicted == h1 + h2 and a.cached_blocks == 0


# ------------------------------------------------------------- sim behaviour
def _shared_reqs(n=8, shared_words=1024, unique_words=63, plen=1088, tlen=32,
                 gap=1.0):
    """A shared-system-prompt stream: arrivals spaced so each prompt's
    prefill commits before the next request is admitted."""
    prefix = _words(shared_words, "sys")
    return [Request(i, prefix + " " + _words(unique_words, f"u{i}"),
                    i * gap, plen, tlen) for i in range(n)]


def test_sim_shared_prefix_cuts_ttft_and_charges_suffix_only():
    cold = simulate(_shared_reqs(), Scheduler(policy=fcfs(), max_batch=8),
                    cost=_cost())
    warm = simulate(_shared_reqs(), Scheduler(policy=fcfs(), max_batch=8),
                    cost=_cost(), prefix_caching=True)
    # the first request is the cold miss that populates the cache
    first = min(warm, key=lambda r: r.arrival_time)
    assert first.cached_prefix_tokens == 0
    hits = [r for r in warm if r is not first]
    assert all(r.cached_prefix_tokens == 1024 for r in hits)   # whole prefix
    ttft = {id(run): [r.first_token_time - r.arrival_time for r in run
                      if r is not min(run, key=lambda q: q.arrival_time)]
            for run in (cold, warm)}
    mean = lambda xs: sum(xs) / len(xs)                        # noqa: E731
    assert mean(ttft[id(warm)]) * 2 < mean(ttft[id(cold)])     # >= 2x better
    assert all(r.tokens_done == r.true_length for r in warm)   # nobody cheated


def test_hit_is_capped_before_the_last_prompt_token():
    """A fully cached prompt still recomputes its final position — the
    backend needs those logits to emit the first output token."""
    reqs = [Request(0, _words(40, "s"), 0.0, 32, 4),
            Request(1, _words(40, "s"), 5.0, 32, 4)]           # identical
    fin = {r.req_id: r for r in simulate(
        reqs, Scheduler(policy=fcfs(), max_batch=2), cost=_cost(),
        prefix_caching=True)}
    assert fin[1].cached_prefix_tokens == 16                   # not 32
    assert fin[1].tokens_done == 4


def test_prefix_cache_survives_retirement_and_feeds_preemption_recompute():
    """Committed prompt blocks park in the LRU at retirement (a much later
    identical prompt still hits), and a preemption victim's recompute
    re-prefill hits its *own* committed prefix on re-admission."""
    late = [Request(0, _words(80, "s"), 0.0, 64, 2),
            Request(1, _words(80, "s"), 50.0, 64, 2)]          # long idle gap
    fin = {r.req_id: r for r in simulate(
        late, Scheduler(policy=fcfs(), max_batch=2), cost=_cost(),
        prefix_caching=True)}
    assert fin[1].cached_prefix_tokens == 48                   # capped 64-16

    reqs = [Request(0, _words(80, "long"), 0.0, 64, 30),
            Request(1, "short one", 0.2, 8, 2)]
    sched = Scheduler(policy=oracle_sjf(), max_batch=1, preemption=True)
    fin = {r.req_id: r for r in simulate(reqs, sched, cost=_cost(),
                                         prefix_caching=True)}
    assert fin[0].preempt_count >= 1
    assert fin[0].cached_prefix_tokens > 0      # recompute reused own prefix
    assert fin[0].tokens_done == 30


# ------------------------------------------------ KV-budget accounting fixes
def test_sharing_admits_within_budget_full_demand_exceeds():
    """B's solo demand is 7 blocks but only 4 are free while A runs; the 3
    cached-prefix blocks it shares with A close the gap — without caching it
    must wait for A to retire."""
    def reqs():
        return [Request(0, _words(80, "s"), 0.0, 64, 16),      # 5 blocks
                Request(1, _words(80, "s"), 0.2, 64, 48)]      # 7 blocks
    kw = dict(cost=_cost(), kv_blocks=9)
    cold = {r.req_id: r for r in simulate(
        reqs(), Scheduler(policy=fcfs(), max_batch=2), **kw)}
    warm = {r.req_id: r for r in simulate(
        reqs(), Scheduler(policy=fcfs(), max_batch=2), prefix_caching=True,
        **kw)}
    assert cold[1].start_time >= cold[0].finish_time           # deferred
    assert warm[1].start_time < warm[0].finish_time            # co-resident
    assert warm[1].cached_prefix_tokens == 48


def test_never_fitting_request_rejected_even_with_cached_prefix():
    """Regression for the old no-progress path: a prefix-cache hit reduces
    prefill work, not simultaneous residency — request 1's full footprint
    (112 tokens = 7 blocks) exceeds the 5-block budget no matter how many
    of those blocks are reusable from the cache, so the gate rejects it
    terminally instead of deferring forever. Request 0 is untouched."""
    from repro.core.scheduler.request import RequestState
    from repro.serving.simulator import make_sim_core

    reqs = [Request(0, _words(80, "s"), 0.0, 64, 16),          # fits: 5 of 5
            Request(1, _words(80, "s"), 10.0, 64, 48)]         # 7 > 5, ever
    core = make_sim_core(Scheduler(policy=fcfs(), max_batch=2), cost=_cost(),
                         kv_blocks=5, prefix_caching=True)
    core.submit(reqs)
    finished = core.run()
    assert [r.req_id for r in finished] == [0]
    assert len(core.dropped) == 1
    r = core.dropped[0]
    assert r.req_id == 1
    assert r.state is RequestState.REJECTED
    assert r.drop_reason == "kv-infeasible"
    assert core.infeasible_rejections == 1


# ----------------------------------------------------------- metrics report
def test_metrics_nan_safe_when_caching_disabled():
    reqs = _shared_reqs(n=4)
    off = report("fcfs", simulate(reqs, Scheduler(policy=fcfs(), max_batch=4),
                                  cost=_cost()))
    assert math.isnan(off.prefix_hit_rate)
    assert math.isnan(off.prefill_tokens_saved)
    on = report("fcfs", simulate(_shared_reqs(n=4),
                                 Scheduler(policy=fcfs(), max_batch=4),
                                 cost=_cost(), prefix_caching=True))
    assert on.prefix_hit_rate == pytest.approx(3 / 4)          # 1 cold miss
    assert on.prefill_tokens_saved == pytest.approx(3 * 1024)


def test_metrics_zero_hits_is_zero_not_nan():
    """Caching on but nothing shareable: 0% is a real measurement."""
    reqs = [Request(i, _words(40, f"solo{i}"), i * 1.0, 32, 4)
            for i in range(3)]
    rep = report("fcfs", simulate(reqs, Scheduler(policy=fcfs(), max_batch=4),
                                  cost=_cost(), prefix_caching=True))
    assert rep.prefix_hit_rate == 0.0
    assert rep.prefill_tokens_saved == 0.0


# -------------------------------------------------- real engine + equivalence
@pytest.fixture(scope="module")
def real_engine_setup():
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm

    cfg = get_smoke_config("llama3_2_3b").replace(dtype="float32",
                                                  vocab_size=2048)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _two_phase_real_run(cfg, params, caching, *, chunk=None):
    """Donor first (populates the cache), then three shared-prefix
    recipients — two-phase submits make the hit pattern deterministic
    without wall-clock arrival races."""
    from repro.serving.engine import Engine

    shared = _words(40, "sys")
    # paged=False: these tests pin the *fragment-store* hit path (install
    # + copy counters); the paged zero-copy hit path is covered by
    # tests/test_paged_decode.py
    eng = Engine(cfg, params, Scheduler(policy=fcfs(), max_batch=4),
                 cache_len=128, prompt_len=64, prefix_caching=caching,
                 prefill_chunk_tokens=chunk, record_tokens=True,
                 paged=False)
    eng.submit([Request(0, shared + " donor tail", 0.0, 49, 4)])
    eng.run()
    eng.submit([Request(10 + i, shared + " " + _words(8, f"u{i}"), 0.0, 49,
                        4 + i) for i in range(3)])
    eng.run()
    assert len(eng.finished) == 4
    assert eng.allocator.used_blocks == 0          # everything released
    return eng


def test_real_engine_outputs_bit_identical_with_prefix_caching(
        real_engine_setup):
    """Acceptance: greedy outputs with caching on equal caching off
    token-for-token on a shared-prefix workload, while the hit path really
    ran (lanes were seeded from the fragment store, not recomputed)."""
    cfg, params = real_engine_setup
    runs = {c: _two_phase_real_run(cfg, params, c) for c in (False, True)}
    outs = {c: {r.req_id: r.generated_tokens for r in eng.finished}
            for c, eng in runs.items()}
    assert outs[True] == outs[False]
    on = runs[True]
    assert on.backend.prefix_installs == 3
    # 40 shared words -> 41 shared ids (CLS included) -> 2 full blocks
    assert on.backend.prefix_tokens_copied == 3 * 32
    assert {r.req_id: r.cached_prefix_tokens for r in on.finished} == {
        0: 0, 10: 32, 11: 32, 12: 32}
    off = runs[False]
    assert off.backend.prefix_installs == 0
    assert all(r.cached_prefix_tokens is None for r in off.finished)


def test_real_engine_prefix_caching_composes_with_chunked_prefill(
        real_engine_setup):
    """A cache-hit admission under a chunk budget streams only the suffix,
    and still matches the uncached, unchunked outputs exactly."""
    cfg, params = real_engine_setup
    base = _two_phase_real_run(cfg, params, False)
    both = _two_phase_real_run(cfg, params, True, chunk=16)
    assert ({r.req_id: r.generated_tokens for r in both.finished}
            == {r.req_id: r.generated_tokens for r in base.finished})
    assert both.backend.prefix_installs == 3
    assert both.backend.extend_dispatches > 0


def test_real_engine_rejects_prefix_caching_for_recurrent_families():
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm
    from repro.serving.engine import Engine

    cfg = get_smoke_config("rwkv6_7b").replace(dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="attention-family"):
        Engine(cfg, params, Scheduler(policy=fcfs(), max_batch=2),
               cache_len=64, prompt_len=16, prefix_caching=True)


def test_cross_backend_admission_order_and_hit_decisions_match(
        real_engine_setup):
    """The same seeded shared-prefix workload, served by the simulator and
    the real engine, admits in the same order and makes identical
    per-request prefix-hit decisions — cache on and cache off."""
    from repro.serving.engine import Engine

    shared = _words(20, "sys")                    # 21 shared ids -> 1 block

    def reqs():
        return [Request(i, shared + " " + _words(20, f"u{i}"), 0.4 * i, 32, 3)
                for i in range(5)]

    for caching in (False, True):
        fin_sim = simulate(reqs(), Scheduler(policy=fcfs(), max_batch=2),
                           cost=_cost(), prefix_caching=caching)
        cfg, params = real_engine_setup
        eng = Engine(cfg, params, Scheduler(policy=fcfs(), max_batch=2),
                     cache_len=64, prompt_len=32, prefix_caching=caching)
        eng.warmup()
        eng.submit(reqs())
        fin_real = eng.run()

        def order(fin):
            return [r.req_id for r in
                    sorted(fin, key=lambda r: (r.start_time, r.req_id))]

        def hits(fin):
            return {r.req_id: r.cached_prefix_tokens for r in fin}

        assert order(fin_sim) == order(fin_real)
        assert hits(fin_sim) == hits(fin_real)
        if caching:
            assert sum(1 for v in hits(fin_sim).values() if v) == 4
