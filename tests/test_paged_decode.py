"""Paged KV serving: the real engine with block-table-pooled KV must be an
*observable no-op* versus contiguous lanes — greedy outputs bit-identical in
every mode combination — while the hit path stops copying KV entirely
(zero-copy block aliasing) and incremental reservation admits more, recovers
from grow failures by preemption, and never deadlocks.
"""
import jax
import pytest

from repro.configs import get_smoke_config
from repro.core.scheduler.policies import fcfs
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.engine import Engine
from repro.serving.kv_cache import BlockAllocator


@pytest.fixture(scope="module")
def setup():
    from repro.models import transformer as tfm

    cfg = get_smoke_config("llama3_2_3b").replace(dtype="float32",
                                                  vocab_size=2048)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _words(n, tag):
    return " ".join(f"{tag}w{j}" for j in range(n))


def _run(cfg, params, paged, *, chunk=None, caching=False, reservation="full",
         allocator=None, cache_len=96, prompt_len=32, max_batch=4, reqs=None):
    if reqs is None:
        shared = _words(24, "sys")
        reqs = [Request(i, shared + " " + _words(6, f"u{i}"), 0.0, 32, 4 + i)
                for i in range(4)]
        reqs += [Request(10 + i, _words(10, f"solo{i}"), 0.0, 32, 5)
                 for i in range(2)]
    eng = Engine(cfg, params, Scheduler(policy=fcfs(), max_batch=max_batch),
                 cache_len=cache_len, prompt_len=prompt_len, paged=paged,
                 prefill_chunk_tokens=chunk, prefix_caching=caching,
                 kv_reservation=reservation, allocator=allocator,
                 record_tokens=True)
    eng.submit(reqs)
    fin = eng.run()
    assert len(fin) == len(reqs)
    return {r.req_id: r.generated_tokens for r in fin}, eng


@pytest.mark.parametrize("chunk,caching", [
    (None, False),            # plain bucketed admission + decode
    (16, False),              # chunked prefill
    (None, True),             # prefix caching (hit resumes mid-prompt)
    (16, True),               # both composed
])
def test_paged_outputs_bit_identical_to_contiguous(setup, chunk, caching):
    """Acceptance: greedy outputs are bit-identical paged vs contiguous,
    including prefix-cache hits and chunked prefill."""
    cfg, params = setup
    contig, _ = _run(cfg, params, False, chunk=chunk, caching=caching)
    paged, eng = _run(cfg, params, True, chunk=chunk, caching=caching)
    assert paged == contig
    assert eng.backend.paged
    assert eng.allocator.used_blocks == 0          # everything released


def test_paged_prefix_hit_copies_zero_tokens(setup):
    """The paged hit path aliases pool blocks into the new request's table:
    ``prefix_installs`` counts the claims, ``prefix_tokens_copied`` stays 0
    (contiguous mode copies the fragments instead)."""
    cfg, params = setup
    shared = _words(30, "sys")

    def two_phase(paged):
        eng = Engine(cfg, params, Scheduler(policy=fcfs(), max_batch=4),
                     cache_len=96, prompt_len=64, paged=paged,
                     prefix_caching=True, record_tokens=True)
        eng.submit([Request(0, shared + " donor", 0.0, 40, 4)])
        eng.run()
        eng.submit([Request(10 + i, shared + " " + _words(4, f"u{i}"),
                            0.0, 40, 4) for i in range(3)])
        eng.run()
        assert len(eng.finished) == 4
        return eng

    off = two_phase(False)
    on = two_phase(True)
    assert ({r.req_id: r.generated_tokens for r in on.finished}
            == {r.req_id: r.generated_tokens for r in off.finished})
    assert on.backend.prefix_installs == off.backend.prefix_installs == 3
    assert off.backend.prefix_tokens_copied > 0    # fragment-store copies
    assert on.backend.prefix_tokens_copied == 0    # zero-copy aliasing
    hits = {r.req_id: r.cached_prefix_tokens for r in on.finished}
    assert hits[0] == 0 and all(hits[10 + i] > 0 for i in range(3))


def test_incremental_reservation_grow_preempts_and_recovers(setup):
    """Under a KV budget too small for every admitted request's full demand,
    incremental reservation over-admits, hits decode-time grow failures,
    preempts deterministically, and still finishes every request with
    correct token counts and a clean allocator."""
    cfg, params = setup
    reqs = [Request(i, _words(8, f"r{i}"), 0.0, 16, 24) for i in range(6)]
    outs, eng = _run(cfg, params, True, reservation="incremental",
                     allocator=BlockAllocator(8, 16), cache_len=48,
                     prompt_len=16, max_batch=6, reqs=reqs)
    fin = eng.finished
    assert all(r.tokens_done == r.true_length for r in fin)
    assert sum(r.grow_failures or 0 for r in fin) > 0
    assert sum(r.grow_preemptions or 0 for r in fin) > 0
    assert sum(r.preempt_count for r in fin) > 0   # victims really evicted
    assert eng.allocator.used_blocks == 0

    # same workload, same budget, full reservation: outputs still identical
    # (admission order may differ; token streams must not)
    reqs2 = [Request(i, _words(8, f"r{i}"), 0.0, 16, 24) for i in range(6)]
    outs_full, eng_full = _run(cfg, params, True, reservation="full",
                               allocator=BlockAllocator(8, 16), cache_len=48,
                               prompt_len=16, max_batch=6, reqs=reqs2)
    assert all(r.grow_failures is None for r in eng_full.finished)


def test_paged_recompute_preemption_matches_contiguous(setup):
    """Preemption + re-admission (recompute semantics) under paged KV:
    outputs still bit-identical to the contiguous engine on the same
    budget-constrained workload."""
    cfg, params = setup

    def constrained(paged):
        reqs = [Request(i, _words(8, f"p{i}"), 0.0, 16, 12) for i in range(5)]
        return _run(cfg, params, paged, allocator=BlockAllocator(6, 16),
                    cache_len=32, prompt_len=16, max_batch=5, reqs=reqs)

    contig, ec = constrained(False)
    paged, ep = constrained(True)
    assert paged == contig
    assert ep.allocator.used_blocks == 0


def test_paged_rejects_unbounded_allocator(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="bounded"):
        Engine(cfg, params, Scheduler(policy=fcfs(), max_batch=2),
               cache_len=64, prompt_len=16, paged=True,
               allocator=BlockAllocator.unbounded(16))


def test_paged_auto_default_skips_recurrent_families():
    """``paged=None`` auto-detects: attention families page, recurrent
    families keep contiguous lanes (their cache is not block-structured)."""
    from repro.models import transformer as tfm

    cfg = get_smoke_config("rwkv6_7b").replace(dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, Scheduler(policy=fcfs(), max_batch=2),
                 cache_len=64, prompt_len=16)
    assert not eng.backend.paged
    with pytest.raises(ValueError, match="attention-family"):
        Engine(cfg, params, Scheduler(policy=fcfs(), max_batch=2),
               cache_len=64, prompt_len=16, paged=True)
