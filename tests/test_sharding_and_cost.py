"""Sharding spec resolution + HLO cost analyzer + training utils."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.hlo_cost import analyze_hlo
from repro.sharding.specs import _resolve, param_specs
from repro.training import (Adam, apply_updates, cosine_schedule,
                            load_checkpoint, save_checkpoint)


def _mesh_1x1():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


# ------------------------------------------------------------- specs
def test_resolve_drops_indivisible_dims():
    mesh = _mesh_1x1()
    # all axes size 1 → divisible, names preserved
    assert _resolve(("fsdp", "model"), (64, 64), mesh) == P("data", "model")


def test_param_specs_cover_all_leaves():
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm
    for arch in ["kimi_k2_1t_a32b", "rwkv6_7b", "hymba_1_5b", "whisper_tiny"]:
        cfg = get_smoke_config(arch).replace(dtype="float32")
        shapes = jax.eval_shape(lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
        specs = param_specs(shapes, _mesh_1x1())
        assert (len(jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "spec")))
                == len(jax.tree.leaves(shapes)))


# ------------------------------------------------------------- hlo cost
def test_hlo_cost_single_matmul():
    txt = (jax.jit(lambda x, w: x @ w)
           .lower(jnp.zeros((128, 128)), jnp.zeros((128, 128)))
           .compile().as_text())
    cs = analyze_hlo(txt)
    assert cs.flops == pytest.approx(2 * 128 ** 3, rel=0.01)


def test_hlo_cost_scan_trip_count():
    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        return jax.lax.scan(body, x, ws)[0]
    txt = (jax.jit(scanned)
           .lower(jnp.zeros((128, 128)), jnp.zeros((10, 128, 128)))
           .compile().as_text())
    cs = analyze_hlo(txt)
    assert cs.flops == pytest.approx(10 * 2 * 128 ** 3, rel=0.01)


def test_hlo_cost_nested_scan():
    def nested(x, ws):
        def outer(c, wrow):
            def inner(c2, w):
                return c2 @ w, None
            return jax.lax.scan(inner, c, wrow)[0], None
        return jax.lax.scan(outer, x, ws)[0]
    txt = (jax.jit(nested)
           .lower(jnp.zeros((128, 128)), jnp.zeros((3, 5, 128, 128)))
           .compile().as_text())
    assert analyze_hlo(txt).flops == pytest.approx(15 * 2 * 128 ** 3, rel=0.01)


# ------------------------------------------------------------- training
def test_adam_minimizes_quadratic():
    opt = Adam(learning_rate=0.1)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, tree, metadata={"step": 7})
    out = load_checkpoint(path, jax.tree.map(jnp.zeros_like, tree))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_param_specs_tp_only_drops_fsdp_axis():
    """fsdp=False (weight-resident decode, §Perf B4) must never use 'data'."""
    import numpy as np
    from jax.sharding import Mesh
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    cfg = get_smoke_config("command_r_35b").replace(dtype="float32")
    shapes = jax.eval_shape(lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
    specs = param_specs(shapes, mesh, fsdp=False)
    for s in jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "spec")):
        flat = []
        for part in s.spec:
            if part is None:
                continue
            flat.extend(part if isinstance(part, tuple) else (part,))
        assert "data" not in flat and "pod" not in flat
