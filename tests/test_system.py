"""End-to-end behaviour: the full PARS pipeline (synthetic corpus → pairwise
predictor → SJF scheduling) must beat FCFS and approach Oracle, per the
paper's headline claim (fast, reduced-scale variant of benchmarks/)."""
import numpy as np
import pytest

from repro.core.predictor import TrainSettings, evaluate_tau, train_predictor
from repro.core.scheduler.policies import fcfs, make_policy, oracle_sjf
from repro.core.scheduler.scheduler import Scheduler
from repro.data.synthetic import make_corpus, sample_lengths
from repro.data.workload import burst_arrivals, make_requests
from repro.serving.simulator import run_policy, simulate


@pytest.fixture(scope="module")
def pipeline():
    c_train = make_corpus("alpaca", 800, seed=0)
    c_test = make_corpus("alpaca", 300, seed=42)
    L_train = sample_lengths(c_train, "gpt4")
    L_test = sample_lengths(c_test, "gpt4", run_seed=9)
    st = TrainSettings(method="pairwise", epochs=2, pairs_per_epoch=2560,
                       delta=0.2)
    pred = train_predictor(c_train.prompts, L_train, settings=st)
    return pred, c_test, L_test


def test_predictor_learns_ranking(pipeline):
    pred, c_test, L_test = pipeline
    tau = evaluate_tau(pred, c_test.prompts, L_test)
    assert tau > 0.45, f"pairwise predictor tau too low: {tau}"


def test_pars_between_fcfs_and_oracle(pipeline):
    pred, c_test, L_test = pipeline
    reqs = make_requests(c_test, L_test, burst_arrivals(300))
    rep_f = run_policy(reqs, fcfs(), max_batch=16, starvation_threshold=1e9)
    rep_p = run_policy(reqs, make_policy("pars", pred), max_batch=16,
                       starvation_threshold=1e9)
    rep_o = run_policy(reqs, oracle_sjf(), max_batch=16,
                       starvation_threshold=1e9)
    # PARS strictly better than FCFS, and ordered toward Oracle
    assert rep_p.avg_per_token_latency < rep_f.avg_per_token_latency
    assert rep_o.avg_per_token_latency <= rep_p.avg_per_token_latency * 1.001
    assert rep_p.p90_per_token_latency < rep_f.p90_per_token_latency


def test_starvation_prevention_every_request_completes(pipeline):
    pred, c_test, L_test = pipeline
    reqs = make_requests(c_test, L_test, burst_arrivals(300))
    sched = Scheduler(policy=make_policy("pars", pred), max_batch=16,
                      starvation_threshold=30.0)
    fin = simulate(reqs, sched)
    assert len(fin) == 300
    waits = np.array([r.start_time - r.arrival_time for r in fin])
    assert np.isfinite(waits).all()
