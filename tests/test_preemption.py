"""Recompute-preemption (beyond-paper scheduler feature) invariants."""
import numpy as np

from repro.core.scheduler.policies import oracle_sjf
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.simulator import CostModel, simulate


def _req(i, true_len, arrival=0.0):
    return Request(i, f"p{i}", arrival, 8, true_len)


def test_preemption_rescues_short_job_behind_long_blocker():
    """Adversarial HOL case: a 1000-token job is alone at t=0 and admitted
    (batch=1); short jobs arrive right after. Without preemption they wait
    out the long job; with preemption they run first."""
    def build():
        return [_req(0, 1000, 0.0)] + [_req(i, 5, 1.0) for i in range(1, 6)]

    cost = CostModel(iter_base_s=0.01, per_seq_s=0.0, prefill_per_token_s=0.0)
    base = Scheduler(policy=oracle_sjf(), max_batch=1)
    fin0 = {r.req_id: r for r in simulate(build(), base, cost=cost)}
    pre = Scheduler(policy=oracle_sjf(), max_batch=1, preemption=True)
    fin1 = {r.req_id: r for r in simulate(build(), pre, cost=cost)}

    # short jobs finish much earlier with preemption
    assert fin1[1].finish_time < 0.2 * fin0[1].finish_time
    # the long job was preempted and still completed fully
    assert fin1[0].preempt_count >= 1
    assert fin1[0].tokens_done == 1000


def test_preemption_respects_cap_and_boost():
    reqs = [_req(0, 500, 0.0)] + [_req(i, 1, float(i)) for i in range(1, 50)]
    sched = Scheduler(policy=oracle_sjf(), max_batch=1, preemption=True,
                      max_preemptions=2, starvation_threshold=3.0)
    cost = CostModel(iter_base_s=0.01, per_seq_s=0.0, prefill_per_token_s=0.0)
    fin = simulate(reqs, sched, cost=cost)
    assert len(fin) == 50
    assert all(r.preempt_count <= 2 for r in fin)


def test_preemption_off_means_no_evictions():
    reqs = [_req(0, 100, 0.0)] + [_req(i, 1, 0.5) for i in range(1, 8)]
    sched = Scheduler(policy=oracle_sjf(), max_batch=2, preemption=False)
    fin = simulate(reqs, sched)
    assert all(r.preempt_count == 0 for r in fin)


def test_preemption_releases_kv_reservation():
    """Budgeted run: a victim's blocks must come back on eviction, or the
    long job could never be re-admitted (the run would raise MemoryError)."""
    cost = CostModel(iter_base_s=0.01, per_seq_s=0.0, prefill_per_token_s=0.0)
    # long job: (8+1000)/16 → 63 blocks; shorts: (8+5)/16 → 1 block each
    reqs = [_req(0, 1000, 0.0)] + [_req(i, 5, 1.0) for i in range(1, 4)]
    sched = Scheduler(policy=oracle_sjf(), max_batch=2, preemption=True)
    fin = {r.req_id: r for r in simulate(reqs, sched, cost=cost, kv_blocks=64)}
    assert set(fin) == {0, 1, 2, 3}
    assert fin[0].preempt_count >= 1
    assert all(r.tokens_done == r.true_length for r in fin.values())


def test_real_backend_preserves_progress_on_readmission():
    """Re-admitting a preempted request on the real path must keep its decode
    progress and TTFT (recompute semantics, matching SimBackend)."""
    import jax
    from repro.configs import get_smoke_config
    from repro.core.scheduler.policies import fcfs
    from repro.models import transformer as tfm
    from repro.serving.engine import Engine

    cfg = get_smoke_config("llama3_2_3b").replace(dtype="float32",
                                                  vocab_size=2048)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, Scheduler(policy=fcfs(), max_batch=2),
                 cache_len=64, prompt_len=16)
    victim = _req(0, 100)
    victim.tokens_done, victim.preempt_count = 37, 1
    victim.first_token_time = 0.5
    eng.backend.prefill([(victim, 0, eng.backend.prefill_total(victim))],
                        now=1.0)
    assert victim.tokens_done == 37
    assert victim.first_token_time == 0.5
    fresh = _req(1, 10)
    eng.backend.prefill([(fresh, 0, eng.backend.prefill_total(fresh))],
                        now=2.0)
    assert fresh.tokens_done == 1
    assert fresh.first_token_time is not None


def test_recompute_cost_charged_on_readmission():
    """The simulator charges prompt + generated tokens on re-admission."""
    cost = CostModel(iter_base_s=0.0, per_seq_s=0.0, prefill_per_token_s=1.0)
    reqs = [_req(0, 50, 0.0), _req(1, 2, 1.0)]
    sched = Scheduler(policy=oracle_sjf(), max_batch=1, preemption=True)
    fin = {r.req_id: r for r in simulate(reqs, sched, cost=cost)}
    # long job: initial prefill 8 + re-prefill (8 + progress) after eviction
    assert fin[0].preempt_count == 1
    assert fin[0].finish_time > fin[1].finish_time
