"""Fault-tolerance layer: predictor degradation ladder, replica crash
failover, per-request deadlines, overload shedding, grow storms, and the
no-fault bit-identity guarantee (an empty fault schedule changes nothing)."""
import math

import pytest

from repro.core.scheduler.policies import UNSCORED_KEY, fcfs, predictor_sjf
from repro.core.scheduler.request import Request, RequestState
from repro.core.scheduler.scheduler import Scheduler
from repro.serving import (FaultSchedule, GrowStorm, ReplicaCrash,
                           ReplicaCrashed, ScorerOutage)
from repro.serving.metrics import report
from repro.serving.simulator import (CostModel, make_sim_core,
                                     make_sim_replicas, simulate,
                                     simulate_replicas)


def _cost():
    return CostModel(iter_base_s=0.01, per_seq_s=0.0, prefill_per_token_s=0.0)


def _reqs(n, plen=8, tlen=8, stagger=0.0, deadline=None):
    return [Request(i, f"req {i} words", i * stagger, plen, tlen,
                    deadline=deadline) for i in range(n)]


def _len_scorer(prompts):
    return [float(len(p)) for p in prompts]


# ------------------------------------------------- predictor degradation unit
class FlakyScorer:
    """Raises for the first ``fail_first`` calls, then scores by length."""

    def __init__(self, fail_first):
        self.fail_first = fail_first
        self.calls = 0

    def __call__(self, prompts):
        self.calls += 1
        if self.calls <= self.fail_first:
            raise RuntimeError(f"scorer down (call {self.calls})")
        return _len_scorer(prompts)


def test_policy_degrades_after_budget_and_recovers():
    pol = predictor_sjf("pars", FlakyScorer(fail_first=2),
                        scorer_failure_budget=2)
    reqs = _reqs(3)
    pol.annotate(reqs)                      # failure 1
    assert pol.scorer_failures == 1 and not pol.degraded
    assert pol.needs_rescore
    pol.annotate(reqs)                      # failure 2 → budget hit
    assert pol.degraded and pol.degradations == 1
    # degraded: FCFS keys for everyone, scored or not
    assert [pol.key(r) for r in reqs] == [r.arrival_time for r in reqs]
    pol.rescore(reqs)                       # recovery probe succeeds
    assert not pol.degraded and pol.recoveries == 1
    pol.rescore(reqs)                       # scores the still-unscored batch
    assert all(r.scored for r in reqs)
    assert not pol.needs_rescore
    assert pol.consecutive_failures == 0


def test_unscored_requests_rank_last_only_while_failure_outstanding():
    pol = predictor_sjf("pars", FlakyScorer(fail_first=1),
                        scorer_failure_budget=5)
    reqs = _reqs(2)
    pol.annotate(reqs)                      # fails: batch left unscored
    assert pol.key(reqs[0]) == UNSCORED_KEY
    pol.rescore(reqs)                       # retry succeeds
    assert pol.key(reqs[0]) == reqs[0].score != UNSCORED_KEY
    # hand-scored requests outside any failure window keep their rank
    fresh = predictor_sjf("pars", _len_scorer)
    r = Request(9, "x", 0.0, 4, 4)
    r.score, r.scored = 7.0, False
    assert fresh.key(r) == 7.0


def test_scorer_timeout_counts_against_budget():
    import time

    def slow(prompts):
        time.sleep(0.05)
        return _len_scorer(prompts)

    pol = predictor_sjf("pars", slow, scorer_failure_budget=1,
                        scorer_timeout_s=0.001)
    pol.annotate(_reqs(1))
    assert pol.scorer_failures == 1 and pol.degraded


def test_degradation_end_to_end_in_simulation():
    faults = FaultSchedule(scorer_outages=(ScorerOutage(first_call=0,
                                                        n_calls=2),))
    pol = predictor_sjf("pars", faults.wrap_scorer(_len_scorer),
                        scorer_failure_budget=2)
    reqs = _reqs(8, tlen=6, stagger=0.05)
    fin = simulate(reqs, Scheduler(policy=pol, max_batch=4), cost=_cost(),
                   faults=faults)
    assert len(fin) == 8                      # outage never loses a request
    assert faults.injected_scorer_faults == 2
    assert pol.degradations == 1 and pol.recoveries == 1
    assert not pol.degraded                   # healed before the run ended
    # requests still waiting at recovery (and all later arrivals) were
    # scored; only work admitted *during* the outage may stay unscored
    assert sum(r.scored for r in fin) >= 4
    rep = report("pars", fin, scorer_failures=pol.scorer_failures,
                 degradations=pol.degradations, recoveries=pol.recoveries)
    assert rep.scorer_failures == 2.0
    assert rep.predictor_degradations == 1.0
    assert rep.predictor_recoveries == 1.0
    # fault counters stay NaN-absent for a run with no fault layer
    assert math.isnan(report("pars", fin).scorer_failures)


# ------------------------------------------------------------ crash / failover
def test_crash_failover_conserves_requests():
    faults = FaultSchedule(crashes=(ReplicaCrash(replica=0, at_step=4,
                                                 down_events=40),))
    reqs = _reqs(24, tlen=6, stagger=0.02)
    rt = simulate_replicas(reqs, n_replicas=2, policy_factory=fcfs,
                           routing="round_robin", cost=_cost(),
                           faults=faults)
    assert faults.injected_crashes == 1
    assert rt.crash_count[0] == 1 and rt.restarts[0] == 1
    fin, dropped = rt.finished, rt.all_dropped
    assert len(fin) + len(dropped) == len(reqs)          # conservation
    assert all(r.tokens_done == r.true_length for r in fin)
    # the crashed replica's in-flight work was re-dispatched and absorbed
    assert rt.redispatches >= 1
    assert sum(r.failovers or 0 for r in fin) >= 1
    rep = rt.report()
    assert rep.crashes == (1, 0) and rep.restarts == (1, 0)
    assert rep.failover_redispatches >= 1


def test_failover_budget_exhaustion_is_terminal_failed():
    cores = make_sim_replicas(2, fcfs, cost=_cost(), kv_blocks=None,
                              block_size=16)
    rt = __import__("repro.serving.router",
                    fromlist=["ReplicaRouter"]).ReplicaRouter(
        cores, policy="round_robin", max_failovers=1, failover_backoff_s=0.0)
    req = Request(0, "doomed", 0.0, 8, 8)
    rt.submit([req])
    assert rt.step()                          # dispatches to replica 0
    idx = rt.assignments[0]
    rt._fail_replica(idx)                     # crash 1: retry queued
    assert req.failovers == 1 and req in rt._retry
    rt.restart_replica(idx)
    while rt._retry:                          # drain the backoff queue
        assert rt.step()
    idx2 = rt.assignments[0]
    rt._fail_replica(idx2)                    # crash 2: budget exhausted
    assert req.state is RequestState.FAILED
    assert req.drop_reason == "failover-budget"
    assert rt.dropped == [req] and req not in rt._retry
    rt.restart_replica(idx2)
    assert rt.run() == []                     # drains clean, nothing lost
    assert len(rt.finished) + len(rt.all_dropped) == 1


def test_exponential_backoff_on_repeated_failover():
    cores = make_sim_replicas(2, fcfs, cost=_cost(), kv_blocks=None,
                              block_size=16)
    from repro.serving.router import ReplicaRouter
    rt = ReplicaRouter(cores, policy="round_robin", max_failovers=5,
                       failover_backoff_s=0.5)
    req = Request(0, "bouncy", 0.0, 8, 8)
    rt.submit([req])
    rt.step()
    t0 = cores[rt.assignments[0]].clock.now()
    rt._fail_replica(rt.assignments[0])
    assert req.route_after == pytest.approx(t0 + 0.5)     # 0.5 · 2^0
    req.failovers = 2                                      # as if crash #2 hit
    rt.restart_replica([i for i, h in enumerate(rt.healthy) if not h][0])
    while 0 not in rt.assignments:                         # retry re-routes
        assert rt.step()
    idx = rt.assignments[0]
    t1 = cores[idx].clock.now()
    rt._fail_replica(idx)                                  # crash #3
    assert req.route_after == pytest.approx(t1 + 0.5 * 2 ** 2)


def test_crashed_core_probes_raise():
    core = make_sim_core(Scheduler(policy=fcfs(), max_batch=2), cost=_cost())
    core.inject_crash()
    for probe in (core.queue_depth, core.kv_pressure, core.tick):
        with pytest.raises(ReplicaCrashed):
            probe()
    core.restart()
    assert core.queue_depth() == 0            # alive again


# ----------------------------------------------------------------- deadlines
def test_in_flight_deadline_cancellation():
    reqs = [Request(0, "slow one", 0.0, 8, 50, deadline=0.2),
            Request(1, "quick", 0.0, 8, 5)]
    core = make_sim_core(Scheduler(policy=fcfs(), max_batch=2), cost=_cost())
    core.submit(reqs)
    fin = core.run()
    assert [r.req_id for r in fin] == [1]
    assert len(core.dropped) == 1
    r = core.dropped[0]
    assert r.state is RequestState.CANCELLED and r.drop_reason == "deadline"
    assert 0 < r.tokens_done < r.true_length   # cancelled mid-decode
    assert core.deadline_cancels == 1
    assert core.allocator.used_blocks == 0     # blocks freed on cancel
    rep = report("fcfs", fin, dropped=core.dropped)
    assert rep.deadline_cancelled == 1.0 and rep.dropped_total == 1.0


def test_admission_denies_unmeetable_deadline():
    """With a per-token service estimate, a request whose predicted service
    time already overruns its deadline is cancelled before admission —
    zero tokens are burnt on it."""
    hopeless = Request(0, "long", 0.0, 8, 100, deadline=0.5)
    hopeless.score, hopeless.scored = 100.0, True    # predicted 100 tokens
    ok = Request(1, "short", 0.0, 8, 5, deadline=10.0)
    core = make_sim_core(Scheduler(policy=fcfs(), max_batch=2), cost=_cost(),
                         deadline_time_per_token=0.01)   # 100 tok → 1s > 0.5
    core.submit([hopeless, ok])
    fin = core.run()
    assert [r.req_id for r in fin] == [1]
    assert core.dropped[0].req_id == 0
    assert core.dropped[0].state is RequestState.CANCELLED
    assert core.dropped[0].tokens_done == 0


# ------------------------------------------------------------- load shedding
def test_sustained_overload_sheds_worst_ranked_tail():
    reqs = _reqs(8, tlen=20)
    core = make_sim_core(Scheduler(policy=fcfs(), max_batch=1), cost=_cost(),
                         shed_queue_depth=2, shed_sustain_steps=2)
    core.submit(reqs)
    fin = core.run()
    assert core.shed_count > 0
    shed = [r for r in core.dropped if r.state is RequestState.SHED]
    assert len(shed) == core.shed_count
    assert all(r.drop_reason == "overload" for r in shed)
    assert len(fin) + len(core.dropped) == len(reqs)
    # fcfs sheds the worst-ranked (latest) arrivals, never the head
    assert 0 not in {r.req_id for r in shed}
    rep = report("fcfs", fin, dropped=core.dropped)
    assert rep.shed == float(core.shed_count)


def test_one_step_burst_never_sheds():
    reqs = _reqs(8, tlen=2)
    core = make_sim_core(Scheduler(policy=fcfs(), max_batch=8), cost=_cost(),
                         shed_queue_depth=2, shed_sustain_steps=3)
    core.submit(reqs)
    fin = core.run()
    # queue drains within the sustain window: overload was never sustained
    assert core.shed_count == 0 and len(fin) == 8


def test_shed_gate_refuses_long_predicted_work_under_overload():
    reqs = _reqs(6, tlen=10)
    for r in reqs:
        r.score, r.scored = 5.0, True
    long_req = Request(9, "predicted long", 0.0, 8, 10)
    long_req.score, long_req.scored = 500.0, True
    core = make_sim_core(Scheduler(policy=fcfs(), max_batch=1), cost=_cost(),
                         shed_queue_depth=2, shed_sustain_steps=2,
                         shed_predicted_tokens=100.0)
    core.submit([*reqs, long_req])
    core.run()
    dropped_ids = {r.req_id for r in core.dropped}
    assert 9 in dropped_ids                   # the long one was refused
    assert all(r.state is RequestState.SHED for r in core.dropped)


# ------------------------------------------------------------- grow storms
def test_grow_storm_self_preempts_and_recovers():
    # first grow happens once a request's decode overflows its admission
    # reservation (prompt + one block = 32 tokens → ~step 26 at these
    # lengths); the storm must straddle it
    faults = FaultSchedule(grow_storms=(GrowStorm(replica=0, start_step=2,
                                                  end_step=40),))
    reqs = _reqs(4, plen=8, tlen=40)
    fin = simulate(reqs, Scheduler(policy=fcfs(), max_batch=4), cost=_cost(),
                   kv_blocks=64, kv_reservation="incremental", faults=faults)
    assert faults.injected_grow_denials > 0
    assert len(fin) == 4                      # the storm loses nothing
    assert all(r.tokens_done == r.true_length for r in fin)
    assert sum(r.grow_failures or 0 for r in fin) > 0


# ------------------------------------------- routing-aware starvation escape
def test_affinity_starved_request_escapes_to_other_replica():
    """Replica 0 is pinned full by a long request; a later request routed
    there would wait out the whole drain. With the escape bound it
    re-routes to the idle replica 1 after K gate rejections."""
    long_a = Request(0, "occupier a", 0.0, 16, 80)       # 6 blocks, slow
    short_b = Request(1, "occupier b", 0.0, 16, 4)       # replica 1, quick
    stuck = Request(2, "starved", 0.1, 16, 40)           # rr → replica 0
    rt = simulate_replicas([long_a, short_b, stuck], n_replicas=2,
                           policy_factory=fcfs, routing="round_robin",
                           cost=_cost(), kv_blocks=6, block_size=16,
                           max_batch=2, affinity_escape_after=3)
    fin = rt.finished
    assert len(fin) == 3
    assert rt.redispatches >= 1               # the escape actually fired
    assert rt.assignments[2] == 1             # ended up on the other replica
    # escaping must beat waiting for replica 0's drain: request 2 starts
    # before the occupier finishes
    by_id = {r.req_id: r for r in fin}
    assert by_id[2].start_time < by_id[0].finish_time


def test_escape_disabled_keeps_request_on_routed_replica():
    long_a = Request(0, "occupier a", 0.0, 16, 80)
    short_b = Request(1, "occupier b", 0.0, 16, 4)
    stuck = Request(2, "starved", 0.1, 16, 40)
    rt = simulate_replicas([long_a, short_b, stuck], n_replicas=2,
                           policy_factory=fcfs, routing="round_robin",
                           cost=_cost(), kv_blocks=6, block_size=16,
                           max_batch=2, affinity_escape_after=None)
    assert len(rt.finished) == 3
    assert rt.redispatches == 0
    assert rt.assignments[2] == 0             # stayed put, waited out drain


# ------------------------------------------------------- no-fault bit-identity
def _trace(fin):
    return [(r.req_id, r.start_time, r.first_token_time, r.finish_time)
            for r in sorted(fin, key=lambda r: r.req_id)]


def test_empty_fault_schedule_is_bit_identical_single_core():
    reqs_a = _reqs(10, tlen=12, stagger=0.03)
    reqs_b = _reqs(10, tlen=12, stagger=0.03)
    base = simulate(reqs_a, Scheduler(policy=fcfs(), max_batch=4),
                    cost=_cost(), kv_blocks=32)
    hooked = simulate(reqs_b, Scheduler(policy=fcfs(), max_batch=4),
                      cost=_cost(), kv_blocks=32, faults=FaultSchedule())
    assert _trace(base) == _trace(hooked)


def test_empty_fault_schedule_is_bit_identical_router():
    def run(faults):
        return simulate_replicas(_reqs(12, tlen=8, stagger=0.02),
                                 n_replicas=2, policy_factory=fcfs,
                                 routing="least_kv_pressure", seed=3,
                                 cost=_cost(), kv_blocks=32, faults=faults)
    a, b = run(None), run(FaultSchedule())
    assert _trace(a.finished) == _trace(b.finished)
    assert a.assignment_log == b.assignment_log


def test_chaos_schedule_is_deterministic_under_fixed_seed():
    def run():
        faults = FaultSchedule.chaos(seed=7, n_replicas=2, horizon_steps=30,
                                     n_crashes=1, restart_events=25,
                                     n_scorer_outages=0, n_grow_storms=0,
                                     arrival_skew_s=0.05)
        rt = simulate_replicas(_reqs(16, tlen=6, stagger=0.02),
                               n_replicas=2, policy_factory=fcfs,
                               routing="round_robin", cost=_cost(),
                               faults=faults)
        return _trace(rt.finished), _trace(rt.all_dropped)
    assert run() == run()
