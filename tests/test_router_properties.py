"""Property-based router-invariant suite for multi-replica serving.

Randomized arrive / route / step / preempt / retire sequences against a
:class:`ReplicaRouter` over 1–3 sim replicas (every routing policy, both KV
reservation modes, prefix caching on, tight budgets) must preserve the
router's conservation laws at every step:

* **no request lost or duplicated across replicas** — every submitted
  request lives in exactly one place (router pending, or exactly one
  replica's pending/waiting/running/finished), and only in the replica it
  was assigned to;
* **per-replica KV accounting stays conserved** — each replica's allocator
  satisfies ``free + used == total`` plus the full refcount/LRU invariant
  set from the prefix-cache property suite;
* **every retired request completed on exactly one replica** — at drain,
  the union of replica ``finished`` lists is exactly the submitted set,
  each request in its assigned replica, and every allocator is clean.

Runs under real ``hypothesis`` when installed (deterministic bounded "ci"
profile, override with ``HYPOTHESIS_PROFILE=``) and under the seeded
fallback shim otherwise — same contract as the prefix-cache suite.
"""
import os
from collections import Counter

from _hypothesis_compat import given, st
from test_prefix_cache_properties import _check_invariants

from repro.core.scheduler.policies import oracle_sjf
from repro.core.scheduler.request import Request, RequestState
from repro.serving.router import ROUTING_POLICIES, ReplicaRouter
from repro.serving.simulator import make_sim_replicas

try:                                   # fixed profile: bounded + derandomized
    import hypothesis

    hypothesis.settings.register_profile(
        "ci", max_examples=60, deadline=None, derandomize=True)
    hypothesis.settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE",
                                                    "ci"))
except ModuleNotFoundError:
    pass

BS = 4          # allocator block size: small so sharing/eviction fire often


def _prompt(variant: int, rid: int) -> str:
    """Prompt families sharing block-aligned word prefixes (variant % 4
    shared blocks with the base family), then a per-request unique tail."""
    shared = (variant % 4) * BS
    return (" ".join(f"sys{k}" for k in range(shared)) + " " +
            " ".join(f"u{rid}w{j}" for j in range(8)))


def _census(router: ReplicaRouter, submitted: dict) -> None:
    """The conservation law: each submitted request sits in exactly one
    container of exactly one owner, and replica containers only ever hold
    requests assigned to that replica. Fault containers count too — a
    failover retry (no assignment while in backoff), a router-level
    ``FAILED`` drop, and per-replica terminal drops are all places a
    request may legitimately be, but never two of them at once."""
    locations = Counter()
    for r in router._pending:
        locations[r.req_id] += 1
        # not routed yet: must not carry an assignment
        assert r.req_id not in router.assignments
    for r in router._retry:
        locations[r.req_id] += 1
        # stripped on crash; re-assigned only when the retry re-routes
        assert r.req_id not in router.assignments
    for r in router.dropped:
        locations[r.req_id] += 1
        assert r.state is RequestState.FAILED
    for i, core in enumerate(router.replicas):
        for container in (core._pending, core.scheduler.waiting,
                          core.scheduler.running, core.finished,
                          core.dropped):
            for r in container:
                locations[r.req_id] += 1
                assert router.assignments.get(r.req_id) == i, \
                    f"req {r.req_id} in replica {i} but assigned " \
                    f"{router.assignments.get(r.req_id)}"
    assert locations == Counter({rid: 1 for rid in submitted}), \
        "request lost or duplicated across replicas"
    # the dispatch log re-routes exactly ``redispatches`` times
    logged = [rid for rid, _ in router.assignment_log]
    assert len(logged) == len(set(logged)) + router.redispatches


def _force_preempt(core) -> None:
    """Evict the worst-ranked running block holder back to W — the same
    recompute eviction the scheduler and the grow-denial path perform —
    so randomized sequences exercise mid-flight eviction under routing."""
    pool = [v for v in core.scheduler.running
            if core.allocator.reserved(v.req_id)]
    if not pool:
        return
    victim = max(pool, key=lambda v: (core.scheduler.policy.key(v), v.req_id))
    core.scheduler.running.remove(victim)
    victim.state = RequestState.WAITING
    victim.preempt_count += 1
    victim.prefilled_tokens = 0
    victim.prefill_target = None
    core.scheduler.evict_hook(victim)
    core.scheduler.waiting.append(victim)


@given(n=st.integers(min_value=1, max_value=3),
       pol=st.integers(min_value=0, max_value=3),
       incremental=st.booleans(),
       budget=st.integers(min_value=8, max_value=20),
       codes=st.lists(st.integers(min_value=0, max_value=1 << 20),
                      min_size=1, max_size=120))
def test_random_routed_lifecycle_preserves_invariants(n, pol, incremental,
                                                      budget, codes):
    cores = make_sim_replicas(
        n, oracle_sjf, kv_blocks=budget, block_size=BS, max_batch=3,
        prefill_chunk_tokens=6, prefix_caching=True,
        kv_reservation="incremental" if incremental else "full")
    router = ReplicaRouter(cores, policy=ROUTING_POLICIES[pol], seed=7)
    submitted, next_id, t = {}, 0, 0.0
    for code in codes:
        op = code % 4
        if op == 0:                                       # arrive
            variant = (code >> 2) % 6
            # demand ≤ (20 + 4) tokens = 6 blocks < the smallest budget, so
            # a wedged replica is impossible and MemoryError never fires
            plen = 4 + (code >> 4) % 16
            out = 1 + (code >> 8) % 4
            req = Request(next_id, _prompt(variant, next_id), t, plen, out)
            router.submit([req])
            submitted[next_id] = req
            next_id += 1
            t += 0.05
        elif op == 1:                                     # one global event
            router.step()
        elif op == 2:                                     # a burst of events
            for _ in range(4):
                router.step()
        elif op == 3:                                     # forced preemption
            _force_preempt(cores[(code >> 2) % n])
        _census(router, submitted)
        for core in cores:
            _check_invariants(core.allocator)
    router.run()                                          # drain everything
    # every retired request completed on exactly one replica — its own
    fin_ids = [r.req_id for core in cores for r in core.finished]
    assert sorted(fin_ids) == sorted(submitted)
    for rid, req in submitted.items():
        owner = router.assignments[rid]
        assert any(f is req for f in cores[owner].finished)
        assert req.tokens_done == req.true_length
    # and every allocator is clean: nothing held after retirement
    for core in cores:
        _check_invariants(core.allocator)
        assert core.allocator.used_blocks == 0
        assert core.allocator.free_blocks == core.allocator.total_blocks
        for rid in submitted:
            assert core.allocator.reserved(rid) == 0


# ------------------------------------------------------- faulty lifecycles
class _TogglableScorer:
    """Shared scorer whose failure mode the op stream flips on and off —
    the policy-level degradation ladder runs *inside* the routed
    lifecycle, not just in isolation."""

    def __init__(self):
        self.broken = False

    def __call__(self, prompts):
        if self.broken:
            raise RuntimeError("injected outage")
        return [float(len(p)) for p in prompts]


@given(n=st.integers(min_value=1, max_value=3),
       pol=st.integers(min_value=0, max_value=3),
       incremental=st.booleans(),
       budget=st.integers(min_value=8, max_value=20),
       codes=st.lists(st.integers(min_value=0, max_value=1 << 20),
                      min_size=1, max_size=120))
def test_faulty_routed_lifecycle_preserves_invariants(n, pol, incremental,
                                                      budget, codes):
    """The no-fault suite's conservation laws, now under injected replica
    crashes, cold restarts, scorer outages, and forced deadline expiry:
    nothing is ever lost or duplicated, and at drain every request is
    finished or terminally dropped — never silently gone."""
    from repro.core.scheduler.policies import predictor_sjf

    scorer = _TogglableScorer()

    def policy_factory():
        return predictor_sjf("pars", scorer, scorer_failure_budget=2)

    cores = make_sim_replicas(
        n, policy_factory, kv_blocks=budget, block_size=BS, max_batch=3,
        prefill_chunk_tokens=6, prefix_caching=True,
        kv_reservation="incremental" if incremental else "full")
    router = ReplicaRouter(cores, policy=ROUTING_POLICIES[pol], seed=7,
                           max_failovers=2, failover_backoff_s=0.01)
    # crashes always restart a few events later, so a drain can never
    # stall behind a permanently dead pool
    router.on_replica_down = (
        lambda rt, idx: rt.schedule_restart(idx, rt.event_count + 3))
    submitted, next_id, t = {}, 0, 0.0
    for code in codes:
        op = code % 8
        if op == 0:                                       # arrive
            variant = (code >> 3) % 6
            plen = 4 + (code >> 5) % 16
            out = 1 + (code >> 9) % 4
            req = Request(next_id, _prompt(variant, next_id), t, plen, out,
                          deadline=t + 1e6)               # far-future SLO
            router.submit([req])
            submitted[next_id] = req
            next_id += 1
            t += 0.05
        elif op == 1:                                     # one global event
            router.step()
        elif op == 2:                                     # a burst of events
            for _ in range(4):
                router.step()
        elif op == 3:                                     # forced preemption
            core = cores[(code >> 3) % n]
            if not core._crashed:
                _force_preempt(core)
        elif op == 4:                                     # kill a replica
            core = cores[(code >> 3) % n]
            if not core._crashed:
                core.inject_crash()        # discovered at the next probe
        elif op == 5:                                     # early cold restart
            idx = (code >> 3) % n
            if not router.healthy[idx]:
                router.restart_replica(idx)
        elif op == 6:                                     # deadline expiry
            core = cores[(code >> 3) % n]
            live = [*core.scheduler.waiting, *core.scheduler.running]
            if live and not core._crashed:
                live[(code >> 5) % len(live)].deadline = -1.0
        elif op == 7:                                     # scorer outage flip
            scorer.broken = not scorer.broken
        _census(router, submitted)
        for core in cores:
            _check_invariants(core.allocator)
    scorer.broken = False                                 # let ranking heal
    router.run()                                          # drain everything
    fin, dropped = router.finished, router.all_dropped
    assert sorted(r.req_id for r in [*fin, *dropped]) == sorted(submitted)
    for r in fin:
        assert r.tokens_done == r.true_length             # finished = complete
    for r in dropped:
        assert r.state in (RequestState.CANCELLED, RequestState.FAILED,
                           RequestState.SHED, RequestState.REJECTED)
        assert r.drop_reason is not None and r.finish_time is not None
    for core in cores:
        _check_invariants(core.allocator)
        assert core.allocator.used_blocks == 0
