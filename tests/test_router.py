"""ReplicaRouter layer: single-replica parity (sim + real backends),
deterministic routing, probe semantics, admit-gate composition, and NaN-safe
metric aggregation for empty replicas.
"""
import dataclasses
import math
import warnings

import numpy as np
import pytest

from repro.core.scheduler.policies import fcfs, oracle_sjf
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.kv_cache import BlockAllocator
from repro.serving.core import ServingCore, VirtualClock
from repro.serving.metrics import report, router_report
from repro.serving.router import ROUTING_POLICIES, ReplicaRouter
from repro.serving.simulator import (CostModel, SimBackend, make_sim_replicas,
                                     simulate, simulate_replicas)


def _words(n, tag):
    return " ".join(f"{tag}w{j}" for j in range(n))


def _trace(n=28, seed=0, families=3, shared_words=40, out_skew=False):
    """Shared-system-prompt trace: ``families`` prompt families sharing a
    ``shared_words``-word prefix, unique per-request tails, PARS score set
    to the true output length (a perfect predictor stand-in)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        fam = int(rng.integers(families))
        prompt = _words(shared_words, f"sys{fam}") + " " + _words(6, f"u{i}")
        out = int(rng.choice([4, 40], p=[0.8, 0.2])) if out_skew \
            else 3 + i % 5
        r = Request(i, prompt, float(i) * 0.07, shared_words + 6, out)
        r.score = float(out)
        reqs.append(r)
    return reqs


def _copy(reqs):
    out = []
    for r in reqs:
        c = Request(r.req_id, r.prompt, r.arrival_time, r.prompt_len,
                    r.true_length)
        c.score = r.score
        out.append(c)
    return out


def _per_request(finished):
    return {r.req_id: (r.start_time, r.first_token_time, r.finish_time,
                       r.tokens_done, r.cached_prefix_tokens)
            for r in finished}


def _assert_reports_equal(a, b):
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, float) and math.isnan(va):
            assert isinstance(vb, float) and math.isnan(vb), f.name
        else:
            assert va == vb, (f.name, va, vb)


# ------------------------------------------------------------ N=1 parity (sim)
@pytest.mark.parametrize("routing", ROUTING_POLICIES)
def test_single_replica_sim_parity(routing):
    """A one-replica router must be an observable no-op versus a bare
    ServingCore run: identical per-request timestamps and equal metrics,
    whatever the routing policy."""
    kw = dict(kv_blocks=64, block_size=16, prefill_chunk_tokens=64,
              prefix_caching=True)
    bare = simulate(_copy(_trace()), Scheduler(policy=fcfs(), max_batch=8),
                    **kw)
    router = simulate_replicas(_copy(_trace()), n_replicas=1,
                               policy_factory=fcfs, routing=routing,
                               max_batch=8, **kw)
    assert _per_request(router.finished) == _per_request(bare)
    _assert_reports_equal(report("parity", bare),
                          report("parity", router.finished))
    assert all(idx == 0 for _rid, idx in router.assignment_log)


def test_single_replica_sim_parity_incremental():
    """Parity must also hold when the tight incremental-reservation budget
    forces grow failures and recompute preemptions inside the replica."""
    trace = _trace(n=20, out_skew=True)
    kw = dict(kv_blocks=12, block_size=16, kv_reservation="incremental")
    bare = simulate(_copy(trace), Scheduler(policy=fcfs(), max_batch=8), **kw)
    router = simulate_replicas(_copy(trace), n_replicas=1,
                               policy_factory=fcfs,
                               routing="predicted_shortest_queue",
                               max_batch=8, **kw)
    assert _per_request(router.finished) == _per_request(bare)
    rep = report("x", bare)
    assert rep.grow_preemptions > 0      # the stress actually fired
    _assert_reports_equal(rep, report("x", router.finished))


# ----------------------------------------------------------- N=1 parity (real)
def test_single_replica_real_parity(setup_real):
    """Real backend: wrapping an Engine's core in a one-replica router must
    reproduce the bare run's greedy tokens bit-identically."""
    cfg, params = setup_real

    def build():
        from repro.serving.engine import Engine
        return Engine(cfg, params, Scheduler(policy=fcfs(), max_batch=4),
                      cache_len=96, prompt_len=32, prefix_caching=True,
                      record_tokens=True)

    def reqs():
        shared = _words(24, "sys")
        return [Request(i, shared + " " + _words(4, f"u{i}"), 0.0, 30, 3 + i)
                for i in range(4)]

    eng1 = build()
    eng1.submit(reqs())
    bare = {r.req_id: r.generated_tokens for r in eng1.run()}

    eng2 = build()
    router = ReplicaRouter([eng2.core], policy="prefix_affinity")
    router.submit(reqs())
    routed = {r.req_id: r.generated_tokens for r in router.run()}
    assert routed == bare
    assert eng2.allocator.used_blocks == 0


@pytest.fixture(scope="module")
def setup_real():
    import jax

    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm

    cfg = get_smoke_config("llama3_2_3b").replace(dtype="float32",
                                                  vocab_size=2048)
    return cfg, tfm.init_params(jax.random.PRNGKey(0), cfg)


# ------------------------------------------------------- deterministic routing
@pytest.mark.parametrize("routing", ROUTING_POLICIES)
def test_fixed_trace_routing_is_deterministic(routing):
    """Fixed trace + fixed policy ⇒ identical replica-assignment sequence
    and identical per-request timings across runs."""
    runs = []
    for _ in range(2):
        router = simulate_replicas(
            _copy(_trace(n=40, families=4)), n_replicas=3,
            policy_factory=oracle_sjf, routing=routing, seed=3,
            kv_blocks=48, block_size=16, max_batch=4,
            prefill_chunk_tokens=64, prefix_caching=True)
        runs.append((list(router.assignment_log),
                     _per_request(router.finished)))
    assert runs[0] == runs[1]
    assert len(runs[0][0]) == 40         # every request routed exactly once


def test_deterministic_under_grow_preemption():
    """Determinism must survive the incremental-reservation preemption path:
    grow denials evict mid-decode, probes see the churn, and the assignment
    sequence still reproduces exactly."""
    trace = _trace(n=30, out_skew=True, seed=5)
    runs = []
    for _ in range(2):
        router = simulate_replicas(
            _copy(trace), n_replicas=2, policy_factory=fcfs,
            routing="least_kv_pressure", seed=1,
            kv_blocks=10, block_size=16, max_batch=6,
            kv_reservation="incremental")
        rep = router.report()
        runs.append((list(router.assignment_log),
                     _per_request(router.finished)))
        assert rep.aggregate.grow_preemptions > 0
    assert runs[0] == runs[1]


# ------------------------------------------------------------------- probes
def _one_core(**kw):
    kw.setdefault("kv_blocks", 16)
    kw.setdefault("block_size", 4)
    kw.setdefault("prefix_caching", True)
    return make_sim_replicas(1, fcfs, **kw)[0]


def test_probe_queue_depth_counts_pending_and_queued():
    core = _one_core()
    core.submit([Request(0, _words(8, "a"), 0.0, 8, 2),
                 Request(1, _words(8, "b"), 5.0, 8, 2)])
    assert core.queue_depth() == 2       # both pending count, even future ones
    core.run()
    assert core.queue_depth() == 0


def test_probe_kv_pressure_bounded_and_unbounded():
    core = _one_core(kv_blocks=16, block_size=4)
    assert core.kv_pressure() == 0.0 and core.kv_used_blocks() == 0
    core.allocator.allocate(0, 12)               # 12 tokens = 3 blocks of 4
    assert core.kv_used_blocks() == 3
    assert core.kv_pressure() == pytest.approx(3 / 16)
    core.allocator.free(0)
    # unbounded allocators report zero pressure but still expose used blocks
    unb = _one_core(kv_blocks=None, block_size=4)
    unb.allocator.allocate(1, 20)                # 5 blocks
    assert unb.kv_pressure() == 0.0 and unb.kv_used_blocks() == 5
    unb.allocator.free(1)


def test_probe_predicted_remaining_tokens():
    core = _one_core()
    r = Request(0, _words(10, "a"), 3.0, 10, 4)
    r.score = 7.0
    core.submit([r])
    # nothing prefilled, nothing decoded: prompt + predicted output
    assert core.predicted_remaining_tokens(lambda q: q.score) \
        == pytest.approx(10 + 7)
    core.run()
    assert core.predicted_remaining_tokens(lambda q: q.score) == 0.0


def test_probe_prefix_affinity_sees_committed_blocks_only():
    shared = _words(12, "sys")                  # 12 tokens = 3 blocks of 4
    core = _one_core()
    probe = Request(7, shared + " " + _words(4, "u7"), 0.0, 16, 2)
    assert core.prefix_affinity_blocks(probe) == 0
    core.submit([Request(0, shared + " " + _words(4, "u0"), 0.0, 16, 2)])
    core.run()
    # donor retired: its committed prefix blocks persist in the LRU pool and
    # the probe sees every whole shared block (the prompt's last block is
    # never counted — a full-prompt hit would leave nothing to prefill)
    assert core.prefix_affinity_blocks(probe) == 3
    # a caching-off replica always reports zero affinity
    off = _one_core(prefix_caching=False)
    assert off.prefix_affinity_blocks(probe) == 0


def test_probe_next_event_time():
    core = _one_core()
    assert core.next_event_time() == float("inf")          # fully drained
    core.submit([Request(0, _words(8, "a"), 9.0, 8, 2)])
    assert core.next_event_time() == 9.0                   # next arrival
    core.tick()                                            # delivers + admits
    assert core.next_event_time() == core.clock.now()      # work is live
    core.run()
    assert core.next_event_time() == float("inf")


# ------------------------------------------------------- admit-gate composition
def test_add_admit_gate_runs_before_reservation():
    """A later-added gate must run *before* the core's KV-reserve hook, so a
    gate veto never leaks a block reservation — while an un-vetoed request
    on the same replica reserves and runs normally. Flipping the gate
    admits the held request through the unchanged base hook."""
    core = _one_core()
    allow = {"open": False}
    core.scheduler.add_admit_gate(lambda r: allow["open"] or r.req_id != 0)
    core.submit([Request(0, _words(8, "a"), 0.0, 8, 6),
                 Request(1, _words(8, "b"), 0.0, 8, 6)])
    core.tick()
    core.tick()
    assert [r.req_id for r in core.scheduler.waiting] == [0]   # vetoed
    assert core.allocator.reserved(0) == 0       # veto leaked no reservation
    assert core.allocator.reserved(1) > 0        # base KV hook still reserves
    allow["open"] = True
    core.run()
    assert len(core.finished) == 2
    assert core.allocator.used_blocks == 0


def test_router_counts_admit_attempts():
    router = simulate_replicas(_copy(_trace(n=10)), n_replicas=2,
                               policy_factory=fcfs, routing="round_robin",
                               kv_blocks=64, block_size=16)
    assert len(router.finished) == 10
    # every served request took at least one admission attempt on its replica
    assert all(a >= c for a, c in zip(router.admit_attempts,
                                      (5, 5)))
    assert router.report().admit_attempts == tuple(router.admit_attempts)


# -------------------------------------------------------- NaN-safe aggregation
def _finished_request(rid, out=3):
    r = Request(rid, "p q r s", float(rid), 4, out)
    r.start_time = r.arrival_time + 0.1
    r.first_token_time = r.arrival_time + 0.2
    r.finish_time = r.arrival_time + 0.2 + 0.05 * out
    r.tokens_done = out
    return r


def test_report_empty_is_all_nan():
    rep = report("empty", [])
    assert rep.n_requests == 0
    for f in dataclasses.fields(rep):
        v = getattr(rep, f.name)
        if isinstance(v, float):
            assert math.isnan(v), f.name     # includes makespan + throughput


def test_router_report_tolerates_empty_replica():
    served = [_finished_request(i) for i in range(4)]
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # numpy empty-slice would raise
        rep = router_report("x", [served, []])
    assert rep.n_requests == 4 and rep.n_replicas == 2
    _assert_reports_equal(rep.aggregate, report("x", served))
    assert rep.requests_per_replica == (4, 0)
    assert rep.load_imbalance == pytest.approx(2.0)   # all load on one of two
    assert rep.token_imbalance == pytest.approx(2.0)
    assert rep.per_replica[1].n_requests == 0
    assert math.isnan(rep.per_replica[1].avg_ttft)
    assert math.isfinite(rep.routed_ttft_mean_s)
    rep.row()                                 # formatting never crashes


def test_router_report_all_empty_is_nan_not_crash():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        rep = router_report("x", [[], [], []])
    assert rep.n_requests == 0
    assert math.isnan(rep.load_imbalance)
    assert math.isnan(rep.token_imbalance)
    assert math.isnan(rep.routed_ttft_mean_s)
    rep.row()


# ---------------------------------------------------------- router validation
def test_router_rejects_bad_config():
    with pytest.raises(ValueError):
        ReplicaRouter([], policy="round_robin")
    with pytest.raises(ValueError):
        ReplicaRouter(make_sim_replicas(1, fcfs), policy="nope")
