"""Workload harness + ServingConfig API (ISSUE 10): trace determinism,
conversation-prefix cache churn, hand-computed SLO attainment, config
round-trips, config-vs-legacy-kwargs bit-identical runs, and RunCounters
legacy-kwarg equivalence."""
import dataclasses
import math
import warnings

import pytest

from repro.core.scheduler.policies import fcfs
from repro.core.scheduler.request import Request, RequestState
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.config import ServingConfig, resolve_config
from repro.serving.core import ServingCore, VirtualClock
from repro.serving.kv_cache import BlockAllocator, prefix_chunk_hashes
from repro.serving.metrics import (RunCounters, meets_itl, meets_ttft,
                                   report, router_report, slo_report)
from repro.serving.simulator import (CostModel, SimBackend, clone_requests,
                                     make_sim_core)
from repro.serving.workloads import (SLO, ArrivalPhase, ConversationSpec,
                                     OutputDist, PriorityClass, TenantSpec,
                                     WorkloadSpec, generate_trace,
                                     trace_summary)


def _conv_spec(seed: int = 3) -> WorkloadSpec:
    """Single-tenant, always-continue 3-turn conversations: every
    conversation's turn t+1 prompt extends its turn t prompt."""
    return WorkloadSpec(
        tenants=(TenantSpec(
            name="chat",
            phases=(ArrivalPhase(rate_per_s=0.6, duration_s=20.0),),
            classes=(PriorityClass("interactive",
                                   slo=SLO(ttft_s=1.0, itl_s=0.25),
                                   priority=1),),
            outputs=OutputDist(median_tokens=8, sigma=0.2),
            conversation=ConversationSpec(max_turns=3, p_continue=1.0,
                                          think_time_s=0.5, turn_words=8,
                                          echo_cap_words=16),
            system_words=64),),
        duration_s=20.0, seed=seed)


def _two_tenant_spec(seed: int = 0) -> WorkloadSpec:
    return WorkloadSpec(
        tenants=(
            TenantSpec(name="a",
                       phases=(ArrivalPhase(2.0, 3.0),
                               ArrivalPhase(0.2, 3.0)),
                       classes=(PriorityClass("gold", slo=SLO(ttft_s=0.5),
                                              priority=1, weight=1.0),
                                PriorityClass("free", weight=2.0)),
                       outputs=OutputDist(median_tokens=12, sigma=0.4)),
            TenantSpec(name="b",
                       phases=(ArrivalPhase(1.0, 6.0),),
                       outputs=OutputDist(median_tokens=40, sigma=0.6,
                                          long_frac=0.2, long_scale=4.0)),
        ),
        duration_s=12.0, seed=seed)


# ------------------------------------------------------- trace determinism
def test_trace_is_a_pure_function_of_the_spec():
    a = generate_trace(_two_tenant_spec(seed=0))
    b = generate_trace(_two_tenant_spec(seed=0))
    assert len(a) == len(b) > 0
    for ra, rb in zip(a, b):
        assert (ra.req_id, ra.prompt, ra.arrival_time, ra.prompt_len,
                ra.true_length, ra.tenant, ra.priority_class, ra.priority,
                ra.slo_ttft_s, ra.slo_itl_s) == \
               (rb.req_id, rb.prompt, rb.arrival_time, rb.prompt_len,
                rb.true_length, rb.tenant, rb.priority_class, rb.priority,
                rb.slo_ttft_s, rb.slo_itl_s)
    # a different seed is a different trace
    c = generate_trace(_two_tenant_spec(seed=1))
    assert [r.prompt for r in c] != [r.prompt for r in a]


def test_trace_shape_and_annotations():
    trace = generate_trace(_two_tenant_spec())
    assert all(trace[i].arrival_time <= trace[i + 1].arrival_time
               for i in range(len(trace) - 1))
    assert [r.req_id for r in trace] == list(range(len(trace)))
    # prompt_len convention: 1 (CLS) + whitespace words
    assert all(r.prompt_len == 1 + len(r.prompt.split()) for r in trace)
    tenants = {r.tenant for r in trace}
    assert tenants == {"a", "b"}
    gold = [r for r in trace if r.priority_class == "gold"]
    assert gold and all(r.slo_ttft_s == 0.5 and r.priority == 1
                        for r in gold)
    # tenant b's default class carries no SLO -> schedules as before
    assert all(r.slo_ttft_s is None and r.priority == 0
               for r in trace if r.tenant == "b")
    summ = trace_summary(trace)
    assert summ["n_requests"] == len(trace)
    assert set(summ["per_tenant"]) == {"a", "b"}


# ------------------------------------------- conversation prefix cache hits
def test_conversation_turns_chain_hash_to_shared_prefixes():
    trace = generate_trace(_conv_spec())
    by_prompt = sorted(trace, key=lambda r: r.arrival_time)
    chains = 0
    for a in by_prompt:
        ext = [b for b in by_prompt
               if b is not a and b.prompt.startswith(a.prompt + " ")]
        for b in ext:
            # whole-block chunk hashes of the shorter prompt are a prefix
            # of the longer one's chain — exactly what the KV prefix cache
            # keys sharing on
            ta = [0] + [hash(w) for w in a.prompt.split()]
            tb = [0] + [hash(w) for w in b.prompt.split()]
            ha, hb = (prefix_chunk_hashes(t, 16) for t in (ta, tb))
            assert hb[:len(ha)] == ha and len(ha) >= 4
            chains += 1
    assert chains > 0, "no multi-turn conversation in the window"


def test_conversation_trace_produces_real_prefix_cache_hits():
    trace = generate_trace(_conv_spec())
    sched = Scheduler(policy=fcfs(), max_batch=8)
    core = make_sim_core(sched, kv_blocks=4096,
                         config=ServingConfig(prefix_caching=True))
    core.submit(clone_requests(trace))
    fin = core.run()
    assert len(fin) == len(trace)
    # every non-first request shares at least the tenant's 64-word system
    # prompt with an earlier one; committed-prefix sharing must kick in
    hits = [r for r in fin if (r.cached_prefix_tokens or 0) > 0]
    assert len(hits) >= len(fin) // 2
    # later turns reuse more than the system prompt: their cached prefix
    # covers the previous turn's whole prompt (minus the partial block)
    ext = {b.req_id: a for a in trace for b in trace
           if b.prompt.startswith(a.prompt + " ")}
    deep = [r for r in fin if r.req_id in ext
            and (r.cached_prefix_tokens or 0)
            >= ext[r.req_id].prompt_len - 16]
    assert deep, "no turn reused its conversation's previous-turn prefix"


# -------------------------------------------------- hand-computed SLO math
def _req(i, *, arrival=0.0, out=10, first=None, finish=None,
         state=RequestState.FINISHED, cls=None, tenant=None, prio=0,
         ttft=None, itl=None, token_times=()):
    r = Request(i, f"p{i}", arrival, 4, out, tenant=tenant,
                priority_class=cls, priority=prio, slo_ttft_s=ttft,
                slo_itl_s=itl)
    r.state = state
    r.first_token_time, r.finish_time = first, finish
    r.token_times.extend(token_times)
    return r


def test_meets_ttft_hand_cases():
    assert meets_ttft(_req(0, first=0.5)) is None            # no SLO
    assert meets_ttft(_req(1, ttft=1.0, first=0.5)) is True
    assert meets_ttft(_req(2, ttft=1.0, first=2.0)) is False
    assert meets_ttft(_req(3, ttft=1.0, first=None,
                           state=RequestState.SHED)) is False


def test_meets_itl_hand_cases():
    assert meets_itl(_req(0, first=1.0, finish=2.0)) is None  # no SLO
    assert meets_itl(_req(1, itl=0.1, state=RequestState.SHED)) is False
    assert meets_itl(_req(2, itl=0.1, out=1, first=1.0, finish=1.0)) is True
    # recorded token times: gaps (0.1, 0.2) -> mean 0.15
    r = _req(3, itl=0.2, out=3, first=1.0, finish=1.3,
             token_times=(1.0, 1.1, 1.3))
    assert meets_itl(r) is True
    assert meets_itl(_req(4, itl=0.1, out=3, first=1.0, finish=1.3,
                          token_times=(1.0, 1.1, 1.3))) is False
    # no token times: (finish - first) / (n - 1) = 0.9 / 9 = 0.1
    assert meets_itl(_req(5, itl=0.1, out=10, first=1.0,
                          finish=1.9)) is True
    assert meets_itl(_req(6, itl=0.09, out=10, first=1.0,
                          finish=1.9)) is False


def test_slo_report_hand_computed_fixture():
    gold = dict(cls="gold", tenant="a", prio=1, ttft=1.0)
    fin = [
        _req(0, first=0.5, finish=2.0, out=10, **gold),   # meets
        _req(1, first=3.0, finish=4.0, out=10, **gold),   # TTFT miss
        _req(3, first=1.0, finish=5.0, out=20,
             cls="free", tenant="b"),                     # no SLO
    ]
    dropped = [_req(2, state=RequestState.SHED, out=10, **gold)]
    s = slo_report("x", fin, dropped)

    assert (s.n_requests, s.n_finished, s.n_dropped) == (4, 3, 1)
    assert s.makespan_s == pytest.approx(5.0)
    g = s.cls("gold")
    assert (g.n_requests, g.n_finished, g.n_dropped) == (3, 2, 1)
    assert g.priority == 1
    assert g.ttft_attainment == pytest.approx(1 / 3)      # drop = miss
    assert g.slo_attainment == pytest.approx(1 / 3)
    assert math.isnan(g.itl_attainment)                   # no ITL SLO
    assert g.goodput_tok_s == pytest.approx(10 / 5.0)     # only req 0
    assert g.throughput_tok_s == pytest.approx(20 / 5.0)
    f = s.cls("free")
    assert math.isnan(f.slo_attainment)                   # NaN-when-absent
    assert f.goodput_tok_s == pytest.approx(20 / 5.0)     # nothing to violate
    assert s.slo_attainment == pytest.approx(1 / 3)       # over gold only
    assert s.goodput_tok_s == pytest.approx((10 + 20) / 5.0)
    assert s.throughput_tok_s == pytest.approx((10 + 20 + 10) / 5.0)
    assert {t.name for t in s.per_tenant} == {"a", "b"}
    with pytest.raises(KeyError):
        s.cls("nope")


def test_slo_report_empty_run_is_all_nan():
    s = slo_report("x", [], [])
    assert s.n_requests == 0
    assert math.isnan(s.slo_attainment) and math.isnan(s.goodput_tok_s)


# ----------------------------------------------- ServingConfig round trips
def test_config_round_trip_and_defaults():
    cfg = ServingConfig(prefill_chunk_tokens=64, prefix_caching=True,
                        rerank_every_steps=4, shed_queue_depth=32)
    assert ServingConfig.from_kwargs(**cfg.to_kwargs()) == cfg
    assert ServingConfig.from_kwargs(**ServingConfig().to_kwargs()) \
        == ServingConfig()
    assert cfg.rerank_enabled and cfg.shed_enabled
    assert not ServingConfig().rerank_enabled
    assert not ServingConfig().shed_enabled
    assert cfg.replace(rerank_every_steps=None) \
        == ServingConfig(prefill_chunk_tokens=64, prefix_caching=True,
                         shed_queue_depth=32)


@pytest.mark.parametrize("bad", [
    dict(prefill_chunk_tokens=0),
    dict(kv_reservation="bogus"),
    dict(rerank_interval=-1.0),
    dict(rerank_every_steps=0),
    dict(rerank_pin_after=-1),
    dict(deadline_time_per_token=-0.1),
    dict(shed_queue_depth=-1),
    dict(shed_kv_pressure=1.5),
    dict(shed_sustain_steps=0),
    dict(shed_predicted_tokens=0),
])
def test_config_validation_rejects(bad):
    with pytest.raises(ValueError):
        ServingConfig(**bad)
    with pytest.raises(ValueError):
        ServingConfig().replace(**bad)          # replace re-validates


def test_config_unknown_field_names_the_offender():
    with pytest.raises(TypeError, match="prefil_chunk_tokens"):
        ServingConfig.from_kwargs(prefil_chunk_tokens=64)


def test_resolve_config_rejects_both_forms():
    cfg = ServingConfig(prefix_caching=True)
    assert resolve_config(cfg, {}) is cfg
    assert resolve_config(None, {"prefix_caching": True}) == cfg
    with pytest.raises(TypeError, match="not both"):
        resolve_config(cfg, {"prefix_caching": True})


# -------------------------------------- legacy kwargs: bit-identical runs
def _sig(fin):
    return sorted((r.req_id, r.start_time, r.first_token_time,
                   r.finish_time, r.cached_prefix_tokens) for r in fin)


def test_legacy_core_kwargs_run_bit_identical_to_config():
    trace = generate_trace(_conv_spec())
    cfg = ServingConfig(prefix_caching=True, prefill_chunk_tokens=32,
                        record_token_times=True)

    via_config = make_sim_core(Scheduler(policy=fcfs(), max_batch=4),
                               kv_blocks=512, config=cfg)
    via_config.submit(clone_requests(trace))
    a = _sig(via_config.run())

    with pytest.warns(DeprecationWarning, match="ServingConfig"):
        legacy = ServingCore(Scheduler(policy=fcfs(), max_batch=4),
                             SimBackend(CostModel()),
                             allocator=BlockAllocator(512, 16),
                             clock=VirtualClock(), **cfg.to_kwargs())
    assert legacy.config == cfg           # the shim built the same config
    legacy.submit(clone_requests(trace))
    b = _sig(legacy.run())

    assert a == b, "legacy kwargs and config= must be the same run"


def test_core_rejects_config_plus_legacy_kwargs():
    with pytest.raises(TypeError, match="not both"):
        ServingCore(Scheduler(policy=fcfs(), max_batch=4),
                    SimBackend(CostModel()), clock=VirtualClock(),
                    config=ServingConfig(), prefix_caching=True)


def test_blessed_helpers_do_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        core = make_sim_core(Scheduler(policy=fcfs(), max_batch=4),
                             kv_blocks=64, prefix_caching=True)
    assert core.config.prefix_caching


# ------------------------------------------- RunCounters legacy equivalence
def _eq_nan(a, b):
    """Structural equality where NaN == NaN (reports use NaN for absent)."""
    if isinstance(a, float) and isinstance(b, float):
        return (math.isnan(a) and math.isnan(b)) or a == b
    if dataclasses.is_dataclass(a):
        return type(a) is type(b) and all(
            _eq_nan(getattr(a, f.name), getattr(b, f.name))
            for f in dataclasses.fields(a))
    if isinstance(a, (tuple, list)):
        return len(a) == len(b) and all(map(_eq_nan, a, b))
    return a == b


def _tiny_finished():
    return [_req(0, first=0.2, finish=1.0, out=5),
            _req(1, arrival=0.1, first=0.4, finish=2.0, out=8)]


def test_report_counters_bundle_equals_legacy_kwargs():
    fin = _tiny_finished()
    dropped = (_req(2, state=RequestState.SHED),)
    legacy = report("p", fin, reranks=7, dropped=dropped,
                    scorer_failures=2, degradations=1, recoveries=1)
    bundled = report("p", fin, counters=RunCounters(
        reranks=7, dropped=dropped, scorer_failures=2, degradations=1,
        recoveries=1))
    assert _eq_nan(legacy, bundled)
    # both forms at once is an API misuse, not a silent merge
    with pytest.raises(TypeError, match="not both"):
        report("p", fin, counters=RunCounters(reranks=7), reranks=7)


def test_router_report_counters_bundle_equals_legacy_kwargs():
    per_replica = [_tiny_finished(), []]
    legacy = router_report("rr", per_replica, admit_attempts=(3, 1),
                           crashes=(1, 0), restarts=(1, 0), redispatches=2)
    bundled = router_report("rr", per_replica, counters=RunCounters(
        admit_attempts=(3, 1), crashes=(1, 0), restarts=(1, 0),
        redispatches=2))
    assert _eq_nan(legacy, bundled)
    with pytest.raises(TypeError, match="not both"):
        router_report("rr", per_replica, admit_attempts=(3, 1),
                      counters=RunCounters(admit_attempts=(3, 1)))


def test_runcounters_from_core_reflects_config():
    sched = Scheduler(policy=fcfs(), max_batch=4)
    core = make_sim_core(sched, kv_blocks=64,
                         config=ServingConfig(rerank_every_steps=2))
    core.submit([Request(0, "a b c", 0.0, 4, 3)])
    core.run()
    c = RunCounters.from_core(core)
    assert c.reranks is not None          # rerank layer was on -> counted
    assert c.dropped is None              # no fault layer -> NaN convention
    plain = make_sim_core(Scheduler(policy=fcfs(), max_batch=4),
                          kv_blocks=64)
    plain.submit([Request(0, "a b c", 0.0, 4, 3)])
    plain.run()
    assert RunCounters.from_core(plain).reranks is None
