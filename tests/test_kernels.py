"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_prefill.ops import flash_attention
from repro.kernels.flash_prefill.ref import flash_prefill_ref
from repro.kernels.flash_decode.ops import (decode_attention_pallas,
                                            paged_decode_attention_pallas)
from repro.kernels.flash_decode.ref import (flash_decode_paged_ref,
                                            flash_decode_ref)
from repro.kernels.rwkv6_chunk.ops import linear_attention_pallas
from repro.kernels.rwkv6_chunk.ref import rwkv6_recurrent_ref
from repro.models.attention import decode_attention
from repro.models.linear_attn import chunked_linear_attention


def _tol(dt):
    return dict(atol=2e-2, rtol=2e-2) if dt == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("b,h,kh,sq,skv,dh", [
    (2, 4, 2, 128, 128, 64),
    (1, 8, 2, 256, 256, 128),
    (2, 4, 4, 96, 96, 64),          # ragged → padding path
    (1, 4, 1, 64, 64, 32),          # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_matches_ref(b, h, kh, sq, skv, dh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, sq, dh), dtype)
    k = jax.random.normal(ks[1], (b, kh, skv, dh), dtype)
    v = jax.random.normal(ks[2], (b, kh, skv, dh), dtype)
    out = flash_attention(q, k, v)
    ref = flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_prefill_sliding_window():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    out = flash_attention(q, k, v, window=64)
    ref = flash_prefill_ref(q, k, v, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("b,h,kh,w,dh,pos,win", [
    (2, 8, 2, 512, 64, 300, None),
    (1, 4, 4, 1024, 128, 800, None),
    (2, 4, 2, 256, 64, 700, 128),    # ring buffer wrapped
    (1, 8, 8, 300, 64, 150, None),   # ragged W → padding
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_model(b, h, kh, w, dh, pos, win, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, h, dh), dtype)
    kc = jax.random.normal(ks[1], (b, w, kh, dh), dtype)
    vc = jax.random.normal(ks[2], (b, w, kh, dh), dtype)
    out = decode_attention_pallas(q, kc, vc, pos, window=win)
    ref = decode_attention(q, kc, vc, pos, window=win)  # XLA twin in the model
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def _paged_setup(seed, b, kh, bs, mb, dh, n_blocks, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    k_pool = jax.random.normal(ks[0], (n_blocks, kh, bs, dh), dtype)
    v_pool = jax.random.normal(ks[1], (n_blocks, kh, bs, dh), dtype)
    # shuffled tables, with deliberate cross-sequence aliasing: every
    # sequence's first block is block 0 (a shared prefix in pool terms)
    rng = np.random.default_rng(seed)
    tables = np.stack([rng.permutation(n_blocks)[:mb] for _ in range(b)])
    tables[:, 0] = 0
    return ks[2], k_pool, v_pool, jnp.asarray(tables, jnp.int32)


@pytest.mark.parametrize("b,h,kh,bs,mb,dh", [
    (2, 8, 2, 16, 8, 64),            # GQA group 4
    (1, 4, 4, 32, 4, 128),           # MHA
    (3, 4, 1, 16, 6, 32),            # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_paged_matches_ref(b, h, kh, bs, mb, dh, dtype):
    kq, k_pool, v_pool, tables = _paged_setup(7, b, kh, bs, mb, dh, 64, dtype)
    q = jax.random.normal(kq, (b, h, dh), dtype)
    # ragged: one full sequence, the rest at assorted partial lengths
    lengths = jnp.asarray([mb * bs - (i * 7) % (mb * bs - 1) if i else mb * bs
                           for i in range(b)], jnp.int32)
    out = paged_decode_attention_pallas(q, k_pool, v_pool, tables, lengths)
    g = h // kh
    ref = flash_decode_paged_ref(q.reshape(b, kh, g, dh), k_pool, v_pool,
                                 tables, lengths).reshape(b, h, dh)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_paged_linear_table_matches_contiguous(dtype):
    """With the identity block table, the paged kernel must agree with the
    contiguous flash_decode on the same (gathered) cache — the table
    indirection itself must not perturb the math."""
    b, h, kh, bs, mb, dh = 2, 8, 2, 16, 8, 64
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    k_pool = jax.random.normal(ks[0], (b * mb, kh, bs, dh), dtype)
    v_pool = jax.random.normal(ks[1], (b * mb, kh, bs, dh), dtype)
    q = jax.random.normal(ks[2], (b, h, dh), dtype)
    tables = jnp.arange(b * mb, dtype=jnp.int32).reshape(b, mb)
    lengths = jnp.asarray([mb * bs, mb * bs - 37], jnp.int32)
    paged = paged_decode_attention_pallas(q, k_pool, v_pool, tables, lengths)
    # contiguous layout: (b, w, kh, dh) cache holding the same rows
    kc = jnp.moveaxis(k_pool.reshape(b, mb, kh, bs, dh), 2, 1) \
        .reshape(b, kh, mb * bs, dh)
    vc = jnp.moveaxis(v_pool.reshape(b, mb, kh, bs, dh), 2, 1) \
        .reshape(b, kh, mb * bs, dh)
    for i in range(b):
        # contiguous pos attends slots [0, pos] inclusive; paged lengths
        # count entries — pos = length - 1 views the same rows
        row = decode_attention_pallas(
            q[i:i + 1], jnp.moveaxis(kc[i:i + 1], 1, 2),
            jnp.moveaxis(vc[i:i + 1], 1, 2), int(lengths[i]) - 1)
        np.testing.assert_allclose(np.asarray(paged[i], np.float32),
                                   np.asarray(row[0], np.float32),
                                   **_tol(dtype))


def test_flash_decode_paged_aliased_tables_share_exactly():
    """Two sequences whose tables alias the same leading blocks and have the
    same length produce bitwise-identical outputs for identical queries —
    the zero-copy sharing guarantee the serving hit path relies on."""
    b, h, kh, bs, mb, dh = 2, 4, 2, 16, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    k_pool = jax.random.normal(ks[0], (32, kh, bs, dh))
    v_pool = jax.random.normal(ks[1], (32, kh, bs, dh))
    q1 = jax.random.normal(ks[2], (1, h, dh))
    q = jnp.concatenate([q1, q1])                 # same query both lanes
    shared = [3, 9, 5]
    tables = jnp.asarray([shared + [11], shared + [20]], jnp.int32)
    lengths = jnp.asarray([3 * bs, 3 * bs], jnp.int32)  # tail block masked
    out = paged_decode_attention_pallas(q, k_pool, v_pool, tables, lengths)
    assert np.array_equal(np.asarray(out[0]), np.asarray(out[1]))


@pytest.mark.parametrize("mode", ["rwkv", "ssd"])
@pytest.mark.parametrize("b,h,t,dk,dv", [
    (2, 4, 128, 64, 64),
    (1, 2, 200, 32, 64),             # ragged T → padding
    (1, 1, 64, 16, 16),
])
def test_rwkv6_chunk_vs_recurrent(mode, b, h, t, dk, dv):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (b, h, t, dk))
    k = jax.random.normal(ks[1], (b, h, t, dk))
    v = jax.random.normal(ks[2], (b, h, t, dv))
    lw_dim = dk if mode == "rwkv" else 1
    lw = -jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, t, lw_dim)))
    u = (jax.random.normal(ks[4], (h, dk)) * 0.1 if mode == "rwkv"
         else jnp.ones((h, dk)))
    out = linear_attention_pallas(q, k, v, lw, u if mode == "rwkv" else None,
                                  mode=mode)
    ref = rwkv6_recurrent_ref(q, k, v, lw, u, mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-4, rtol=5e-4)


def test_model_chunked_linear_attn_vs_recurrent():
    """The model-level chunked path must agree with the recurrence too."""
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    b, h, t, d = 2, 2, 128, 32
    q, k = (jax.random.normal(ks[i], (b, h, t, d)) for i in range(2))
    v = jax.random.normal(ks[2], (b, h, t, d))
    lw = -jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, t, d)))
    u = jax.random.normal(ks[4], (h, d)) * 0.1
    out, _ = chunked_linear_attention(q, k, v, lw, bonus=u, mode="rwkv",
                                      chunk_size=32)
    ref = rwkv6_recurrent_ref(q, k, v, lw, u, mode="rwkv")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-4, rtol=5e-4)
