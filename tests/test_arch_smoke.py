"""Per-architecture smoke tests (deliverable (f)): every assigned arch,
reduced config (2 layers, d_model ≤ 512, ≤ 4 experts), one forward + one
train step on CPU — output shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import build, example_batch
from repro.models import transformer as tfm
from repro.training import Adam, make_train_step

B, S = 2, 64


def _cfg(arch):
    cfg = get_smoke_config(arch).replace(dtype="float32")
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    return cfg


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = _cfg(arch)
    bundle = build(cfg, remat="none")
    params = bundle.init(jax.random.PRNGKey(0))
    batch = example_batch(cfg, B, S, jax.random.PRNGKey(1))
    logits, _, aux = tfm.forward_seq(params, cfg, batch["tokens"],
                                     vision_embeds=batch.get("vision_embeds"),
                                     mrope_positions=batch.get("mrope_positions"),
                                     frames=batch.get("frames"), remat="none")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = _cfg(arch)
    bundle = build(cfg, remat="none")
    params = bundle.init(jax.random.PRNGKey(0))
    batch = example_batch(cfg, B, S, jax.random.PRNGKey(1))
    opt = Adam(learning_rate=1e-3, clip_norm=1.0)
    step = jax.jit(make_train_step(cfg, opt, remat="none"))
    params2, opt_state, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    l1 = jax.tree.leaves(params)[0]
    l2 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


@pytest.mark.parametrize("arch", ["llama3_2_3b", "rwkv6_7b", "hymba_1_5b",
                                  "whisper_tiny", "qwen2_vl_72b"])
def test_prefill_decode_consistency(arch):
    """Decode from a prefilled cache must match the full-sequence forward."""
    cfg = _cfg(arch)
    if cfg.moe is not None:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    bundle = build(cfg, remat="none")
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 32), 0, cfg.vocab_size)
    extras = {}
    if cfg.family == "audio":
        extras["frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.encoder_seq_len, cfg.d_model)) * 0.02
    full, _, _ = tfm.forward_seq(params, cfg, toks, remat="none", **extras)
    _, cache = bundle.prefill(params, toks[:, :28], cache_len=32, **extras)
    for t in range(28, 32):
        step_logits, cache = bundle.decode_step(params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(step_logits),
                                   np.asarray(full[:, t]), atol=2e-4, rtol=2e-3)


def test_sliding_window_cache_bounded():
    """Windowed decode must keep a bounded ring cache and stay consistent."""
    cfg = _cfg("llama3_2_3b").replace(sliding_window=16)
    bundle = build(cfg, remat="none")
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 48), 0, cfg.vocab_size)
    full, _, _ = tfm.forward_seq(params, cfg, toks, remat="none")
    _, cache = bundle.prefill(params, toks[:, :40], cache_len=16)
    assert cache["k"].shape[3 - 1] == 16  # (L, B, W=16, KH, dh)
    for t in range(40, 48):
        logits, cache = bundle.decode_step(params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, t]),
                                   atol=2e-4, rtol=2e-3)
