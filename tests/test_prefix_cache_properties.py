"""Property-based serving-invariant suite for the refcounted prefix cache.

Random admit / extend / shrink / commit / free sequences — with prompts drawn
from families that share block-aligned prefixes, under a small budget so LRU
eviction fires constantly — must preserve the allocator's conservation laws
at every step:

* ``free_blocks + used_blocks == total_blocks``, and the minted ids are
  exactly partitioned into referenced / cached / recycled;
* refcounts never go negative (an entry exists iff at least one request
  holds the block, and equals the number of holders);
* no block leaks: after every reservation is freed, ``used_blocks == 0``
  and the full budget is allocatable again;
* a request never holds blocks after retirement (``reserved == 0`` the
  moment ``free`` returns, idempotently).

Runs under real ``hypothesis`` when installed — a deterministic, bounded
"ci" profile is registered and loaded here (override with
``HYPOTHESIS_PROFILE=<name>``); the tests deliberately carry no
``@settings`` decorators so the profile actually governs them — and under
the seeded fallback shim otherwise.
"""
import os
from collections import Counter

from _hypothesis_compat import given, st

from repro.core.scheduler.policies import fcfs
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving import ServingConfig, ServingCore, VirtualClock
from repro.serving.kv_cache import BlockAllocator, prefix_chunk_hashes
from repro.serving.simulator import CostModel, SimBackend

try:                                   # fixed profile: bounded + derandomized
    import hypothesis

    hypothesis.settings.register_profile(
        "ci", max_examples=60, deadline=None, derandomize=True)
    hypothesis.settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE",
                                                    "ci"))
except ModuleNotFoundError:
    pass

TOTAL, BS = 32, 4


def _stream(variant: int, length: int) -> list:
    """Token stream of one prompt family: variants share a block-aligned
    prefix of 0/4/8/12 tokens with the common base, then diverge."""
    shared = (variant % 4) * BS
    return list(range(shared)) + [1000 + variant * 100 + j
                                  for j in range(max(length - shared, 0))]


def _check_invariants(a: BlockAllocator) -> None:
    # conservation: every minted id is referenced, cached, or recycled
    assert a.free_blocks + a.used_blocks == a.total_blocks
    assert a._minted == a.used_blocks + a.cached_blocks + len(a._free_pool)
    assert a._minted <= a.total_blocks
    # refcount = exact holder multiset; never zero or negative entries
    holders = Counter(b for blocks in a._req_blocks.values() for b in blocks)
    assert dict(holders) == a._refcount
    assert all(rc >= 1 for rc in a._refcount.values())
    # LRU members are exactly the unreferenced committed content blocks
    for b in a._lru:
        assert b not in a._refcount
        assert b in a._block_hash and b in a._committed
    # hash index stays a bijection
    assert len(a._hash_block) == len(a._block_hash)
    for b, h in a._block_hash.items():
        assert a._hash_block[h] == b
    # the free pool is disjoint from live and cached blocks
    assert set(a._free_pool).isdisjoint(a._refcount)
    assert set(a._free_pool).isdisjoint(a._lru)


@given(st.lists(st.integers(min_value=0, max_value=1 << 20),
                min_size=1, max_size=150))
def test_random_lifecycle_preserves_invariants(codes):
    a = BlockAllocator(total_blocks=TOTAL, block_size=BS)
    evicted = []

    def on_evict(h):
        # the index entry is dropped *before* listeners run — a backend can
        # never observe a tracked hash it was just told to forget (the same
        # content may be re-registered by a later identical prompt, so this
        # only holds at notification time)
        assert not a.tracked(h)
        evicted.append(h)

    a.add_evict_listener(on_evict)
    live, next_id = {}, 0
    for code in codes:
        op = code % 5
        if op == 0:                                    # admit
            variant, tokens = (code >> 2) % 6, 4 + (code >> 5) % 40
            ids = _stream(variant, tokens)
            hashes = prefix_chunk_hashes(ids, BS)[:max(tokens - 1, 0) // BS]
            if a.can_allocate(tokens, hashes):
                shared = a.allocate(next_id, tokens, hashes)
                assert 0 <= shared <= len(hashes)
                assert a.reserved(next_id) == a.blocks_for(tokens)
                live[next_id] = tokens
                next_id += 1
        elif op == 1 and live:                         # grow / shrink
            rid = sorted(live)[(code >> 2) % len(live)]
            tokens = 4 + (code >> 5) % 60
            before = a.reserved(rid)
            if a.extend(rid, tokens):
                assert a.reserved(rid) == a.blocks_for(tokens)
                live[rid] = tokens
            else:                                      # denied: state intact
                assert a.reserved(rid) == before
        elif op == 2 and live:                         # prefill completed
            a.commit(sorted(live)[(code >> 2) % len(live)])
        elif op == 3 and live:                         # retire
            rid = sorted(live)[(code >> 2) % len(live)]
            a.free(rid)
            del live[rid]
            assert a.reserved(rid) == 0                # nothing held after
            a.free(rid)                                # idempotent
            assert a.reserved(rid) == 0
        elif op == 4 and live:                         # incremental grow
            # the paged decode path's allocation unit: append n anonymous
            # blocks, all-or-nothing, reservation intact on denial
            rid = sorted(live)[(code >> 2) % len(live)]
            n_blk = (code >> 5) % 4
            before = a.reserved(rid)
            if a.grow(rid, n_blk):
                assert a.reserved(rid) == before + n_blk
                live[rid] = (before + n_blk) * BS
            else:
                assert a.reserved(rid) == before
                assert n_blk > a.free_blocks
        _check_invariants(a)
    for rid in list(live):                             # drain: no leaks
        a.free(rid)
        assert a.reserved(rid) == 0
    _check_invariants(a)
    assert a.used_blocks == 0
    assert a.free_blocks == a.total_blocks
    assert a.can_allocate(TOTAL * BS)                  # full budget reusable


@given(st.lists(st.integers(min_value=0, max_value=1 << 20),
                min_size=2, max_size=40))
def test_mirror_store_tracks_eviction_listener(codes):
    """A backend-style hash-keyed mirror (fragment store) kept via the
    eviction listener never holds content the allocator stopped tracking."""
    a = BlockAllocator(total_blocks=8, block_size=BS)
    mirror = set()
    a.add_evict_listener(mirror.discard)
    rid = 0
    for code in codes:
        variant, tokens = code % 5, 4 + (code >> 3) % 24
        hashes = prefix_chunk_hashes(_stream(variant, tokens), BS)
        hashes = hashes[:max(tokens - 1, 0) // BS]
        if a.can_allocate(tokens, hashes):
            a.allocate(rid, tokens, hashes)
            a.commit(rid)
            mirror.update(h for h in hashes if a.tracked(h))
            if code % 2:                               # retire half of them
                a.free(rid)
            rid += 1
        assert all(a.tracked(h) for h in mirror)
    for r in range(rid):
        a.free(r)
    # flush the LRU under pressure: the mirror must drain with it
    a.allocate(10**6, a.total_blocks * BS)
    assert mirror == set()


@given(n=st.integers(min_value=2, max_value=10),
       shared_words=st.integers(min_value=0, max_value=48),
       budget=st.integers(min_value=8, max_value=40),
       chunk=st.integers(min_value=8, max_value=64),
       incremental=st.booleans())
def test_served_workloads_release_every_block(n, shared_words, budget, chunk,
                                              incremental):
    """End-to-end through the ServingCore: a randomized shared-prefix
    workload under a tight budget (chunked prefill + caching on, both
    reservation modes) finishes with the allocator clean — no request holds
    blocks after retirement, even across grow-failure preemptions."""
    prefix = " ".join(f"sys{i}" for i in range(shared_words))
    reqs = [Request(i, f"{prefix} tail{i} " +
                    " ".join(f"u{i}w{j}" for j in range(12)),
                    0.3 * i, 8 + 4 * (i % 5), 1 + (i % 4)) for i in range(n)]
    alloc = BlockAllocator(total_blocks=budget, block_size=16)
    sched = Scheduler(policy=fcfs(), max_batch=4)
    core = ServingCore(sched, SimBackend(CostModel()), allocator=alloc,
                       clock=VirtualClock(), config=ServingConfig(
                           prefill_chunk_tokens=chunk, prefix_caching=True,
                           kv_reservation="incremental" if incremental
                           else "full"))
    core.submit(reqs)
    finished = core.run()
    assert len(finished) == n
    assert alloc.used_blocks == 0
    assert alloc.free_blocks == alloc.total_blocks
    for r in finished:
        assert alloc.reserved(r.req_id) == 0
        assert r.cached_prefix_tokens is not None      # caching was consulted
        assert (r.grow_failures is not None) == incremental
    _check_invariants(alloc)
