"""Scheduler invariants: unit + hypothesis property tests (deliverable (c))."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.scheduler.policies import fcfs, make_policy, oracle_sjf
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.simulator import CostModel, run_policy, simulate


def _reqs(lengths, arrivals=None):
    arrivals = arrivals if arrivals is not None else [0.0] * len(lengths)
    return [Request(i, f"prompt {i}", float(arrivals[i]), 8, int(lengths[i]))
            for i in range(len(lengths))]


# --------------------------------------------------------------------- units
def test_fcfs_preserves_arrival_order():
    reqs = _reqs([5, 5, 5], arrivals=[2.0, 0.0, 1.0])
    s = Scheduler(policy=fcfs(), max_batch=2)
    s.add_requests(reqs)
    admitted = s.schedule(now=3.0)
    assert [r.req_id for r in admitted] == [1, 2]


def test_oracle_sjf_orders_by_true_length():
    reqs = _reqs([30, 10, 20])
    s = Scheduler(policy=oracle_sjf(), max_batch=2)
    s.add_requests(reqs)
    admitted = s.schedule(now=0.0)
    assert [r.true_length for r in admitted] == [10, 20]


def test_starvation_boost_overrides_sjf():
    reqs = _reqs([1000, 1], arrivals=[0.0, 500.0])
    s = Scheduler(policy=oracle_sjf(), max_batch=1, starvation_threshold=120.0)
    s.add_requests(reqs)
    admitted = s.schedule(now=600.0)         # long req waited 600 s > 2 min
    assert admitted[0].true_length == 1000   # boosted ahead of the short one
    assert admitted[0].boosted


def test_static_batching_waits_for_drain():
    reqs = _reqs([3, 3, 3])
    s = Scheduler(policy=fcfs(), max_batch=2, continuous=False)
    s.add_requests(reqs)
    first = s.schedule(0.0)
    assert len(first) == 2
    assert s.schedule(1.0) == []              # batch not drained yet
    for r in first:
        r.tokens_done = r.true_length
    assert len(s.schedule(2.0)) == 1          # drained → next batch forms


def test_predictor_policy_annotates_scores():
    pol = make_policy("pars", predictor=lambda prompts: [len(p) for p in prompts])
    reqs = _reqs([5, 5])
    reqs[0].prompt = "a much much longer prompt string"
    reqs[1].prompt = "hi"
    s = Scheduler(policy=pol, max_batch=1)
    s.add_requests(reqs)
    admitted = s.schedule(0.0)
    assert admitted[0].req_id == 1            # lower score first


def test_admit_hook_gates_admission_in_rank_order():
    s = Scheduler(policy=fcfs(), max_batch=4)
    s.admit_hook = lambda r: r.req_id != 1          # "no memory" for req 1
    s.add_requests(_reqs([5, 5, 5]))
    admitted = s.schedule(0.0)
    assert [r.req_id for r in admitted] == [0, 2]
    assert [r.req_id for r in s.waiting] == [1]     # stays in W, not dropped
    assert all(r.state.value == "running" for r in admitted)


def test_defer_returns_requests_to_head_of_waiting():
    s = Scheduler(policy=fcfs(), max_batch=3)
    s.add_requests(_reqs([5, 5, 5, 5], arrivals=[0.0, 1.0, 2.0, 3.0]))
    admitted = s.schedule(4.0)
    assert len(admitted) == 3
    s.defer(admitted[1:])
    assert [r.req_id for r in s.running] == [0]
    assert [r.req_id for r in s.waiting] == [1, 2, 3]
    assert s.waiting[0].state.value == "waiting"


# ---------------------------------------------------------------- properties
@settings(max_examples=40, deadline=None)
@given(lengths=st.lists(st.integers(1, 300), min_size=1, max_size=120))
def test_simulation_conserves_requests_and_timestamps(lengths):
    reqs = _reqs(lengths)
    finished = simulate(reqs, Scheduler(policy=oracle_sjf(), max_batch=8))
    assert len(finished) == len(lengths)
    for r in finished:
        assert r.tokens_done == r.true_length
        assert r.finish_time >= r.first_token_time >= r.start_time >= r.arrival_time
        assert r.first_token_time > r.arrival_time - 1e-9


@settings(max_examples=25, deadline=None)
@given(lengths=st.lists(st.integers(1, 400), min_size=8, max_size=100),
       batch=st.integers(1, 8))
def test_oracle_sjf_never_worse_than_fcfs_on_bursts(lengths, batch):
    """With perfect foresight and identical cost model, SJF's mean per-token
    latency on a burst is ≤ FCFS's (classic scheduling result)."""
    base = _reqs(lengths)
    rep_f = run_policy(base, fcfs(), max_batch=batch, starvation_threshold=1e9)
    rep_o = run_policy(base, oracle_sjf(), max_batch=batch,
                       starvation_threshold=1e9)
    assert rep_o.avg_per_token_latency <= rep_f.avg_per_token_latency * 1.001


@settings(max_examples=25, deadline=None)
@given(lengths=st.lists(st.integers(1, 200), min_size=4, max_size=60))
def test_starvation_boost_guarantees(lengths):
    """What the mechanism actually guarantees (paper §III-B): boosted
    requests are served FIFO among themselves ahead of all SJF traffic, and
    every wait is bounded by threshold + full drain of the system."""
    thresh = 5.0
    n = len(lengths)
    arrivals = [0.05 * i for i in range(n)]
    reqs = _reqs(lengths, arrivals=arrivals)
    sched = Scheduler(policy=oracle_sjf(), max_batch=2,
                      starvation_threshold=thresh)
    cost = CostModel(iter_base_s=0.01, per_seq_s=0.0, prefill_per_token_s=0.0)
    finished = simulate(reqs, sched, cost=cost)
    boosted = sorted((r for r in finished if r.boosted),
                     key=lambda r: r.arrival_time)
    # FIFO among boosted: admission order follows arrival order
    for a, b in zip(boosted, boosted[1:]):
        assert a.start_time <= b.start_time + 1e-9
    # global wait bound: threshold + one full serial drain of all tokens
    drain = sum(lengths) * 0.01
    for r in finished:
        assert r.start_time - r.arrival_time <= thresh + drain + 1.0


@settings(max_examples=30, deadline=None)
@given(scores=st.lists(st.floats(-5, 5, allow_nan=False), min_size=2,
                       max_size=50))
def test_ranking_is_total_and_stable(scores):
    reqs = _reqs([10] * len(scores))
    for r, s in zip(reqs, scores):
        r.score = s
    pol = make_policy("pars", predictor=lambda ps: [0] * len(ps))
    sched = Scheduler(policy=pol, max_batch=len(reqs))
    sched.waiting = list(reqs)
    sched._rank()
    keys = [r.score for r in sched.waiting]
    assert keys == sorted(keys)
