"""Predictor units: losses, pairing filter, Kendall τ_b, backbones."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.predictor import (HashTokenizer, PredictorConfig,
                                  build_pairs, init_predictor,
                                  kendall_tau_b, l1_pointwise_loss,
                                  listmle_loss, margin_ranking_loss,
                                  min_length_difference, predictor_forward)


# ------------------------------------------------------------------- losses
def test_margin_ranking_loss_values():
    s_a = jnp.array([2.0, 0.0])
    s_b = jnp.array([0.0, 2.0])
    y = jnp.array([1.0, 1.0])     # A should outrank B
    # pair 1: correct by 2 ≥ margin → 0 ; pair 2: wrong by 2 → 2+1 = 3
    assert float(margin_ranking_loss(s_a, s_b, y, margin=1.0)) == pytest.approx(1.5)


def test_margin_loss_zero_when_separated():
    s_a, s_b = jnp.array([5.0]), jnp.array([0.0])
    assert float(margin_ranking_loss(s_a, s_b, jnp.array([1.0]))) == 0.0
    assert float(margin_ranking_loss(s_b, s_a, jnp.array([-1.0]))) == 0.0


def test_listmle_prefers_correct_order():
    lengths = jnp.array([[3.0, 2.0, 1.0]])
    good = jnp.array([[3.0, 2.0, 1.0]])   # scores aligned with lengths
    bad = jnp.array([[1.0, 2.0, 3.0]])
    assert float(listmle_loss(good, lengths)) < float(listmle_loss(bad, lengths))


def test_l1_pointwise_is_scaled_mae():
    s = jnp.array([1.0, 2.0])
    L = jnp.array([100.0, 300.0])
    assert float(l1_pointwise_loss(s, L)) == pytest.approx(0.5)


# ------------------------------------------------------------------ pairing
def test_min_length_difference_formula():
    # paper eq. (1): |L_A - L_B| / max(L_A, L_B)
    np.testing.assert_allclose(min_length_difference([100], [80]), [0.2])
    np.testing.assert_allclose(min_length_difference([80], [100]), [0.2])


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 10_000), min_size=4, max_size=300),
       st.floats(0.0, 0.9))
def test_build_pairs_respects_delta(lengths, delta):
    lengths = np.asarray(lengths, np.float64)
    ia, ib, y = build_pairs(lengths, np.random.default_rng(0),
                            n_pairs=200, delta=delta)
    assert len(ia) == len(ib) == len(y)
    if len(ia):
        mld = min_length_difference(lengths[ia], lengths[ib])
        assert np.all(mld >= delta - 1e-12)
        assert np.all(y == np.where(lengths[ia] > lengths[ib], 1.0, -1.0))
        assert np.all(ia != ib)


# ------------------------------------------------------------------ tau
def test_kendall_tau_perfect_and_inverse():
    x = [1, 2, 3, 4, 5]
    assert kendall_tau_b(x, x) == pytest.approx(1.0)
    assert kendall_tau_b(x, x[::-1]) == pytest.approx(-1.0)


def test_kendall_tau_ties_match_scipy_convention():
    # hand-checked tau_b with ties
    x = [1, 2, 2, 3]
    y = [1, 3, 2, 4]
    # pairs: n0=6, ties in x: 1 → n1=1; none in y. nc: compare all non-tied-x
    # (1,2)+,(1,2)+,(1,3)+,(2,3)+,(2,3)+ → nc=5, nd=0
    expected = 5 / np.sqrt(5 * 6)
    assert kendall_tau_b(x, y) == pytest.approx(expected)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=2, max_size=60))
def test_kendall_tau_bounded_and_symmetric(xs):
    ys = list(reversed(xs))
    t = kendall_tau_b(xs, ys)
    assert -1.0 - 1e-9 <= t <= 1.0 + 1e-9
    assert kendall_tau_b(xs, xs) >= 0.0 or len(set(xs)) == 1


# ------------------------------------------------------------- backbones
@pytest.mark.parametrize("backbone", ["bert", "opt", "t5"])
def test_backbone_forward_shape_and_pad_invariance(backbone):
    cfg = PredictorConfig(backbone=backbone)
    params = init_predictor(jax.random.PRNGKey(0), cfg)
    tok = HashTokenizer(vocab_size=cfg.vocab_size, max_len=cfg.max_len)
    toks = jnp.asarray(tok.encode_batch(["explain topic3", "what is topic9"]))
    scores = predictor_forward(params, cfg, toks)
    assert scores.shape == (2,)
    assert np.all(np.isfinite(np.asarray(scores)))
    # trailing PAD must not affect the score (mask correctness)
    ids = tok.encode("explain topic3")
    a = np.zeros((1, cfg.max_len), np.int32)
    a[0, :len(ids)] = ids
    b = a.copy()                                  # identical, full PAD tail
    s1 = predictor_forward(params, cfg, jnp.asarray(a))
    s2 = predictor_forward(params, cfg, jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_tokenizer_deterministic_and_bounded():
    tok = HashTokenizer()
    a = tok.encode_batch(["Explain topic3!", "explain TOPIC3"])
    assert (a[0] == a[1]).all()                  # case/punct-insensitive
    assert a.max() < tok.vocab_size and a.min() >= 0
