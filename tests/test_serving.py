"""Serving layer: allocator, metrics, simulator regimes, real-engine smoke."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.scheduler.policies import fcfs, oracle_sjf
from repro.core.scheduler.request import Request
from repro.data.synthetic import make_corpus, sample_lengths
from repro.data.workload import burst_arrivals, make_requests, poisson_arrivals
from repro.models import transformer as tfm
from repro.serving import BlockAllocator, CostModel, run_policy, serve


def test_block_allocator_accounting():
    a = BlockAllocator(total_blocks=10, block_size=16)
    assert a.blocks_for(1) == 1 and a.blocks_for(17) == 2
    a.allocate(1, 100)                  # 7 blocks
    assert a.free_blocks == 3
    assert not a.can_allocate(100)
    assert a.can_allocate(48)
    assert a.extend(1, 130)             # 9 blocks total
    assert a.free_blocks == 1
    assert not a.extend(1, 200)
    a.free(1)
    assert a.free_blocks == 10
    with pytest.raises(MemoryError):
        a.allocate(2, 1000)


def test_poisson_arrivals_monotone_and_rate():
    arr = poisson_arrivals(4000, rate=2.0, seed=0)
    assert np.all(np.diff(arr) >= 0)
    assert arr[-1] == pytest.approx(2000, rel=0.15)


def test_burst_vs_poisson_latency_regimes():
    c = make_corpus("alpaca", 400, seed=1)
    L = sample_lengths(c, "llama")
    burst = make_requests(c, L, burst_arrivals(400))
    sparse = make_requests(c, L, poisson_arrivals(400, rate=0.05, seed=1))
    rb = run_policy(burst, fcfs(), max_batch=16)
    rs = run_policy(sparse, fcfs(), max_batch=16)
    assert rb.avg_per_token_latency > rs.avg_per_token_latency  # queueing hurts


def test_simulator_oracle_beats_fcfs_substantially_on_burst():
    c = make_corpus("alpaca", 500, seed=2)
    L = sample_lengths(c, "llama")
    reqs = make_requests(c, L, burst_arrivals(500))
    rf = run_policy(reqs, fcfs(), max_batch=16, starvation_threshold=1e9)
    ro = run_policy(reqs, oracle_sjf(), max_batch=16, starvation_threshold=1e9)
    assert ro.avg_per_token_latency < 0.5 * rf.avg_per_token_latency
    assert ro.p90_per_token_latency < rf.p90_per_token_latency


def test_real_engine_serves_and_orders_sjf():
    cfg = get_smoke_config("llama3_2_3b").replace(dtype="float32",
                                                  vocab_size=2048)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    c = make_corpus("alpaca", 16, seed=3)
    L = np.clip(sample_lengths(c, "llama"), 1, 60)
    reqs = make_requests(c, L, burst_arrivals(12), indices=range(12))
    rep = serve(cfg, params, reqs, oracle_sjf(), max_batch=4, cache_len=128)
    assert rep.n_requests == 12
    assert rep.avg_per_token_latency > 0
    # SJF: among the burst, shorter jobs must (weakly) start earlier
    starts = {r.req_id: r.start_time for r in reqs}
    lens = {r.req_id: r.true_length for r in reqs}
    first_four = sorted(starts, key=starts.get)[:4]
    assert np.mean([lens[i] for i in first_four]) <= np.mean(list(lens.values()))
