"""BlockAllocator coverage + KV-budget back-pressure through the ServingCore:
both execution modes now get memory-aware admission from the same gate."""
import jax
import numpy as np
import pytest

from repro.core.scheduler.policies import fcfs
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving import BlockAllocator
from repro.serving.simulator import CostModel, simulate


# ----------------------------------------------------------- allocator units
def test_extend_growth_and_denial():
    a = BlockAllocator(total_blocks=8, block_size=16)
    a.allocate(1, 32)                      # 2 blocks
    assert a.extend(1, 64)                 # grow to 4
    assert a.reserved(1) == 4
    assert not a.extend(1, 16 * 9)         # 9 blocks > capacity
    assert a.reserved(1) == 4              # denied extend leaves state intact
    assert a.extend(1, 40)                 # shrink-capable re-reservation
    assert a.reserved(1) == 3


def test_exhaustion_raises_memory_error():
    a = BlockAllocator(total_blocks=4, block_size=16)
    a.allocate(1, 33)                      # 3 blocks
    with pytest.raises(MemoryError):
        a.allocate(2, 33)
    assert a.can_allocate(16) and not a.can_allocate(17)


def test_free_list_reuse_after_free():
    a = BlockAllocator(total_blocks=4, block_size=16)
    a.allocate(1, 64)
    assert a.free_blocks == 0 and a.used_blocks == 4
    a.free(1)
    assert a.free_blocks == 4
    a.allocate(2, 64)                      # freed capacity is reusable
    assert a.reserved(2) == 4
    a.free(99)                             # unknown id is a no-op


def test_unbounded_allocator_never_back_pressures():
    a = BlockAllocator.unbounded()
    for i in range(100):
        assert a.can_allocate(1 << 20)
        a.allocate(i, 1 << 20)


# --------------------------------------------- simulator under a KV budget
def _reqs(n, plen=8, tlen=16):
    return [Request(i, f"p{i}", 0.0, plen, tlen) for i in range(n)]


def _max_concurrency(finished):
    events = sorted([(r.start_time, 1) for r in finished]
                    + [(r.finish_time, -1) for r in finished],
                    key=lambda e: (e[0], e[1]))
    cur = peak = 0
    for _, d in events:
        cur += d
        peak = max(peak, cur)
    return peak


def test_simulator_defers_admission_under_tight_kv_budget():
    """Each request reserves ceil((8+16)/16)=2 blocks; a 4-block budget caps
    concurrency at 2 even though the batch has room for all 6."""
    cost = CostModel(iter_base_s=0.01, per_seq_s=0.0, prefill_per_token_s=0.0)
    free = simulate(_reqs(6), Scheduler(policy=fcfs(), max_batch=6), cost=cost)
    assert all(r.start_time == 0.0 for r in free)     # unbounded: no deferral

    fin = simulate(_reqs(6), Scheduler(policy=fcfs(), max_batch=6),
                   cost=cost, kv_blocks=4)
    assert len(fin) == 6                              # deferred, not dropped
    assert _max_concurrency(fin) <= 2
    assert any(r.start_time > 0.0 for r in fin)       # admission was deferred


def test_never_fitting_request_is_rejected_terminally():
    """A request whose full footprint exceeds total capacity (100+100 tokens
    = 13 blocks of 16 vs a 2-block budget) can never be admitted: the KV
    gate rejects it terminally instead of deferring it forever (the
    historical behaviour was a no-progress MemoryError from the step loop).
    The run completes, the request lands in ``core.dropped`` with a
    distinct terminal state, and the drop is a metric, not an exception."""
    from repro.core.scheduler.request import RequestState
    from repro.serving.metrics import report
    from repro.serving.simulator import make_sim_core

    core = make_sim_core(Scheduler(policy=fcfs(), max_batch=1), kv_blocks=2)
    core.submit([Request(0, "p", 0.0, 100, 100)])
    finished = core.run()
    assert finished == []
    assert len(core.dropped) == 1
    r = core.dropped[0]
    assert r.state is RequestState.REJECTED
    assert r.drop_reason == "kv-infeasible"
    assert r.finish_time is not None
    assert core.infeasible_rejections == 1
    rep = report("fcfs", finished, dropped=core.dropped)
    assert rep.rejected == 1 and rep.dropped_total == 1


def test_rejection_does_not_starve_feasible_requests():
    """One infeasible request in a stream of feasible ones: everyone else
    still finishes, and conservation holds (finished + dropped == n)."""
    from repro.serving.simulator import make_sim_core

    reqs = _reqs(4, plen=8, tlen=16)                   # 2 blocks each
    reqs.append(Request(9, "huge", 0.0, 100, 100))     # 13 blocks > 4
    core = make_sim_core(Scheduler(policy=fcfs(), max_batch=4), kv_blocks=4)
    core.submit(reqs)
    finished = core.run()
    assert len(finished) == 4 and len(core.dropped) == 1
    assert core.dropped[0].req_id == 9


# ------------------------------------------------- real path: bucketed prefill
def test_bucketed_prefill_one_dispatch_per_bucket():
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm
    from repro.serving.engine import Engine

    cfg = get_smoke_config("llama3_2_3b").replace(dtype="float32",
                                                  vocab_size=2048)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    sched = Scheduler(policy=fcfs(), max_batch=8)
    eng = Engine(cfg, params, sched, cache_len=64, prompt_len=16)
    short = "a b c"                                   # 4 tokens → bucket 8
    long = " ".join(f"w{i}" for i in range(14))       # 15 tokens → bucket 16
    reqs = [Request(i, short if i % 2 else long, 0.0, 8, 3) for i in range(6)]
    eng.submit(reqs)
    fin = eng.run()
    assert len(fin) == 6
    assert eng.backend.prefill_requests == 6
    # the whole burst admits in one cycle → one dispatch per distinct bucket
    assert eng.backend.prefill_dispatches == 2
    assert eng.allocator.free_blocks == eng.allocator.total_blocks
    # the scheduler's queues were never poked from outside: every request
    # went W → R → retired through the API
    assert not sched.waiting and not sched.running


def test_sequential_prefill_dispatches_per_request():
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm
    from repro.serving.engine import Engine

    cfg = get_smoke_config("llama3_2_3b").replace(dtype="float32",
                                                  vocab_size=2048)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    sched = Scheduler(policy=fcfs(), max_batch=8)
    eng = Engine(cfg, params, sched, cache_len=64, prompt_len=16,
                 bucketed=False)
    reqs = [Request(i, f"prompt number {i}", 0.0, 4, 2) for i in range(5)]
    eng.submit(reqs)
    fin = eng.run()
    assert len(fin) == 5
    assert eng.backend.prefill_dispatches == 5        # the old per-request path
