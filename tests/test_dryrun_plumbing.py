"""Dry-run plumbing on a 1-device CPU mesh: the same lower()+compile path the
512-device dry-run uses, exercised at smoke scale so it stays test-covered
(the real meshes are covered by results/dryrun_baseline artifacts)."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import INPUT_SHAPES, get_smoke_config
from repro.launch.analysis import analyze, model_flops_estimate
from repro.models import transformer as tfm
from repro.models.model import batch_spec
from repro.sharding.annotate import DEFAULT_RULES, logical_axis_rules
from repro.sharding.specs import batch_specs, param_specs, decode_cache_specs
from repro.training.optimizer import Adam
from repro.training.train_loop import make_train_step


def _mesh():
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


@pytest.mark.parametrize("arch", ["llama3_2_3b", "olmoe_1b_7b", "rwkv6_7b"])
def test_train_step_lowers_and_compiles_on_mesh(arch):
    cfg = get_smoke_config(arch).replace(dtype="float32")
    mesh = _mesh()
    p_shape = jax.eval_shape(lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = param_specs(p_shape, mesh)
    opt = Adam(learning_rate=1e-3)
    o_shape = jax.eval_shape(opt.init, p_shape)
    from repro.sharding.specs import replicated
    o_specs = type(o_shape)(step=replicated(mesh),
                            mu=param_specs(o_shape.mu, mesh),
                            nu=param_specs(o_shape.nu, mesh))
    from repro.models.model import example_batch
    b_shape = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in
               example_batch(cfg, 2, 64, jax.random.PRNGKey(1)).items()}
    b_specs = batch_specs(b_shape, mesh)
    with mesh, logical_axis_rules(mesh, DEFAULT_RULES):
        step = make_train_step(cfg, opt, remat="none", microbatch=2)
        lowered = jax.jit(step, in_shardings=(p_specs, o_specs, b_specs),
                          out_shardings=(p_specs, o_specs, None)).lower(
                              p_shape, o_shape, b_shape)
        compiled = lowered.compile()
    rl = analyze(compiled, arch=arch, shape="smoke", mesh_name="cpu1x1",
                 n_devices=1,
                 model_flops=6.0 * cfg.active_param_count() * 2 * 64)
    assert rl.flops_per_device > 0
    assert rl.bytes_per_device > 0
    assert rl.bottleneck in ("compute", "memory", "collective")


def test_decode_step_lowers_with_cache_specs():
    cfg = get_smoke_config("llama3_2_3b").replace(dtype="float32")
    mesh = _mesh()
    p_shape = jax.eval_shape(lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
    p_specs = param_specs(p_shape, mesh)
    cache_shape = jax.eval_shape(lambda: tfm.init_cache(cfg, 2, 128))
    for kv_shard in ("heads", "seq"):
        c_specs = decode_cache_specs(cache_shape, mesh, kv_shard=kv_shard)
        token = jax.ShapeDtypeStruct((2, 1), np.int32)
        with mesh, logical_axis_rules(mesh, DEFAULT_RULES):
            def serve_step(params, cache, tok):
                return tfm.decode_step(params, cfg, cache, tok)
            compiled = jax.jit(serve_step,
                               in_shardings=(p_specs, c_specs, None),
                               out_shardings=(None, c_specs),
                               donate_argnums=(1,)).lower(
                                   p_shape, cache_shape, token).compile()
        assert compiled is not None
