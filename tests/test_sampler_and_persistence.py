"""Sampler correctness + predictor checkpoint roundtrip + linear-attention
state-handoff properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.sampler import SamplerConfig, sample


def test_greedy_sampler_is_argmax():
    logits = jnp.asarray([[0.1, 2.0, -1.0], [3.0, 0.0, 0.0]])
    out = sample(logits, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(out), [1, 0])


def test_top_k_restricts_support():
    logits = jnp.asarray([0.0, 1.0, 2.0, 3.0])
    cfg = SamplerConfig(temperature=1.0, top_k=2)
    draws = {int(sample(logits, jax.random.PRNGKey(i), cfg)) for i in range(64)}
    assert draws <= {2, 3}


def test_top_p_restricts_support():
    logits = jnp.asarray([10.0, 9.9, -10.0, -10.0])
    cfg = SamplerConfig(temperature=1.0, top_p=0.9)
    draws = {int(sample(logits, jax.random.PRNGKey(i), cfg)) for i in range(64)}
    assert draws <= {0, 1}


def test_temperature_sampling_matches_distribution_roughly():
    logits = jnp.log(jnp.asarray([0.7, 0.2, 0.1]))
    cfg = SamplerConfig(temperature=1.0)
    keys = jax.random.split(jax.random.PRNGKey(0), 2000)
    draws = jax.vmap(lambda k: sample(logits, k, cfg))(keys)
    freq0 = float(jnp.mean(draws == 0))
    assert 0.6 < freq0 < 0.8


# ----------------------------------------------------- predictor persistence
def test_predictor_save_load_roundtrip(tmp_path):
    from repro.core.predictor import TrainSettings, train_predictor
    from repro.core.predictor.train import RankingPredictor
    from repro.data.synthetic import make_corpus, sample_lengths

    c = make_corpus("alpaca", 200, seed=0)
    L = sample_lengths(c, "gpt4")
    pred = train_predictor(c.prompts, L, settings=TrainSettings(
        method="pairwise", epochs=1, pairs_per_epoch=512, delta=0.2))
    path = str(tmp_path / "pred.npz")
    pred.save(path)
    pred2 = RankingPredictor.load(path)
    s1 = pred.score(c.prompts[:16])
    s2 = pred2.score(c.prompts[:16])
    np.testing.assert_allclose(s1, s2, rtol=1e-6)
    assert pred2.method == "pairwise"


# --------------------------------------------- linear-attention state handoff
@pytest.mark.parametrize("mode", ["rwkv", "ssd"])
def test_chunked_state_handoff_equals_full_pass(mode):
    """Processing [0:T/2] then [T/2:T] with the carried state must equal one
    full pass — the invariant prefill-continuation (and the engine) rely on."""
    from repro.models.linear_attn import chunked_linear_attention

    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    b, h, t, d = 2, 2, 128, 32
    q, k = (jax.random.normal(ks[i], (b, h, t, d)) for i in range(2))
    v = jax.random.normal(ks[2], (b, h, t, d))
    lw = -jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, t,
                                                   d if mode == "rwkv" else 1)))
    u = jax.random.normal(ks[4], (h, d)) * 0.1 if mode == "rwkv" else None

    full, state_full = chunked_linear_attention(q, k, v, lw, bonus=u,
                                                mode=mode, chunk_size=32)
    h1, s1 = chunked_linear_attention(q[:, :, :64], k[:, :, :64],
                                      v[:, :, :64], lw[:, :, :64],
                                      bonus=u, mode=mode, chunk_size=32)
    h2, s2 = chunked_linear_attention(q[:, :, 64:], k[:, :, 64:],
                                      v[:, :, 64:], lw[:, :, 64:],
                                      bonus=u, mode=mode, chunk_size=32,
                                      initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], axis=2)),
                               np.asarray(full), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(state_full),
                               atol=1e-5, rtol=1e-5)
