"""Iterative re-ranking (remaining-length-aware scheduling) + the three
scheduler correctness fixes that re-ranking makes hot:

* the ``score == 0.0`` unscored sentinel in ``Policy.annotate`` (a
  legitimate zero score was re-scored on every ``add_requests``),
* dataclass field-wise ``Request.__eq__`` used for queue membership (two
  field-identical requests confused by ``defer``/``_preempt``),
* the doubled ``_boost``/``_rank`` pass per ``schedule`` cycle under
  preemption.

Re-ranking coverage: batched refresh scoring, remaining-key monotonicity,
fixed-trace determinism with re-rank on and off, the pin-after-K-demotions
starvation bound, probe freshness, and router N=1 parity with a rerank
cadence set.
"""
import dataclasses
import math

import pytest

from repro.core.scheduler.policies import (fcfs, make_policy, oracle_sjf,
                                           predictor_sjf)
from repro.core.scheduler.request import Request, RequestState
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.metrics import report
from repro.serving.router import ROUTING_POLICIES
from repro.serving.simulator import (CostModel, make_sim_replicas, simulate,
                                     simulate_replicas)


def _req(i, true_len, arrival=0.0, prompt=None, prompt_len=8):
    return Request(i, prompt if prompt is not None else f"p{i}",
                   arrival, prompt_len, true_len)


class CountingScorer:
    """Batched-dispatch observability: every __call__ is one predictor
    dispatch; ``seen`` accumulates each prompt every time it was scored."""

    def __init__(self, fn=lambda p: 0.0):
        self.fn = fn
        self.calls = 0
        self.seen = []

    def __call__(self, prompts):
        self.calls += 1
        self.seen.extend(prompts)
        return [self.fn(p) for p in prompts]


# ---------------------------------------------------- satellite 1: sentinel
def test_zero_score_is_not_rescored():
    """A predictor that legitimately scores a prompt 0.0 must not be asked
    about it again on every add_requests call (the score==0.0 sentinel
    regression): exactly one scoring per request, ever."""
    scorer = CountingScorer(lambda p: 0.0)
    s = Scheduler(policy=predictor_sjf("pars", scorer), max_batch=4)
    first = [_req(0, 5), _req(1, 5)]
    s.add_requests(first)
    assert all(r.scored and r.score == 0.0 for r in first)
    s.add_requests([_req(2, 5)])
    s.add_request(_req(3, 5))
    # every prompt scored exactly once — no re-dispatch for the zero scores
    assert sorted(scorer.seen) == ["p0", "p1", "p2", "p3"]


def test_annotate_batches_one_call_per_add():
    scorer = CountingScorer(lambda p: float(len(p)))
    s = Scheduler(policy=predictor_sjf("pars", scorer), max_batch=4)
    s.add_requests([_req(i, 5) for i in range(6)])
    assert scorer.calls == 1                    # one batched dispatch


# ----------------------------------------------- satellite 2: identity eq
def _twins():
    """Two distinct requests with bitwise-identical fields (same-prompt
    arrivals in the same tick)."""
    a, b = _req(7, 5, prompt="dup"), _req(7, 5, prompt="dup")
    assert a is not b
    return a, b


def test_request_equality_is_identity():
    a, b = _twins()
    assert a != b                     # not value equality
    assert a == a
    assert len({a, b}) == 2           # hashable by identity → usable in sets


def test_defer_with_field_identical_requests_keeps_the_other():
    """defer([b]) must remove exactly b from R — with dataclass value
    equality ``r not in reqs`` matched a too and silently dropped it."""
    a, b = _twins()
    s = Scheduler(policy=fcfs(), max_batch=2)
    for r in (a, b):
        r.state = RequestState.RUNNING
    s.running = [a, b]
    s.defer([b])
    assert len(s.running) == 1 and s.running[0] is a
    assert len(s.waiting) == 1 and s.waiting[0] is b
    assert a.state is RequestState.RUNNING
    assert b.state is RequestState.WAITING
    assert b.defer_count == 1 and a.defer_count == 0


def test_preempt_evicts_the_chosen_victim_not_its_twin():
    """running.remove(victim) must evict the object _preempt chose, even
    when a field-identical twin sits earlier in R."""
    a, b = _twins()
    s = Scheduler(policy=oracle_sjf(), max_batch=2, preemption=True)
    for r in (a, b):
        r.state = RequestState.RUNNING
    s.running = [a, b]
    s.add_requests([_req(1, 1, arrival=0.0)])   # short candidate
    evicted = []
    s.evict_hook = lambda r: evicted.append(r)
    s.schedule(0.0)
    assert len(evicted) == 1
    # exactly one of the twins is out; the survivor is the *other object*
    assert sum(1 for r in s.running if r in (a, b)) == 1
    survivor = next(r for r in s.running if r in (a, b))
    assert survivor is not evicted[0]


# ---------------------------------------------- satellite 3: single rank
def test_schedule_ranks_waiting_exactly_once_per_cycle():
    """Under preemption the cycle used to boost+sort W once for _preempt
    and a second time before admission; now exactly one rank pass (and
    preemption evictions keep W sorted by insertion, not by re-sorting)."""
    s = Scheduler(policy=oracle_sjf(), max_batch=2, preemption=True)
    longs = [_req(0, 100), _req(1, 90)]
    for r in longs:
        r.state = RequestState.RUNNING
    s.running = list(longs)
    s.add_requests([_req(2, 1), _req(3, 2), _req(4, 3)])
    assert s.rank_passes == 0
    admitted = s.schedule(0.0)
    assert s.rank_passes == 1                   # one sort, reused throughout
    assert admitted                             # preemption freed capacity
    # eviction kept W correctly ordered: victims ranked among the waiters
    keys = [s._sort_key(r) for r in s.waiting]
    assert keys == sorted(keys)
    s.schedule(1.0)
    assert s.rank_passes <= 2                   # still ≤ one per cycle


def test_policy_key_calls_bounded_by_single_sort():
    """Counting key_fn invocations: one schedule cycle without preemption
    costs exactly one key evaluation per waiting request (list.sort calls
    the key once per element, and there is no second sort)."""
    calls = []
    pol = oracle_sjf()
    base_key = pol.key_fn
    pol.key_fn = lambda r: (calls.append(r.req_id) or base_key(r))
    s = Scheduler(policy=pol, max_batch=2)
    s.add_requests([_req(i, 10 + i) for i in range(5)])
    s.schedule(0.0)
    assert len(calls) == 5                      # was 10 with the double rank


# ------------------------------------------------------- batched refresh
def test_refresh_rescored_waiting_in_one_batched_call():
    scorer = CountingScorer(lambda p: float(len(p)))
    s = Scheduler(policy=predictor_sjf("pars", scorer), max_batch=2)
    s.add_requests([_req(i, 5, prompt="x" * (i + 1)) for i in range(6)])
    assert scorer.calls == 1
    n = s.rerank(now=0.0)
    assert n == 6                               # every queued key refreshed
    assert scorer.calls == 2                    # ONE more dispatch, not six
    assert s.rerank_count == 1


def test_refresh_picks_up_updated_predictor():
    """The batched waiting-queue re-score exists so an online-updated
    predictor propagates into the ranks (and probes) without per-request
    dispatch."""
    state = {"scale": 10.0}
    scorer = CountingScorer(lambda p: state["scale"])
    s = Scheduler(policy=predictor_sjf("pars", scorer), max_batch=2)
    r = _req(0, 5)
    s.add_requests([r])
    assert r.score == 10.0
    state["scale"] = 3.0                        # predictor got better
    s.rerank(now=1.0)
    assert r.score == 3.0
    assert r.remaining_est == 3.0


def test_fcfs_refresh_is_a_noop():
    s = Scheduler(policy=fcfs(), max_batch=2)
    r = _req(0, 5, arrival=2.5)
    s.add_requests([r])
    assert s.rerank(now=1.0) == 0
    assert r.remaining_est is None
    assert s.policy.key(r) == 2.5               # key stays arrival time


# -------------------------------------------------- remaining-length keys
def test_running_key_never_increases_as_tokens_done_grows():
    """Remaining-length monotonicity: across refreshes, a running request's
    key is non-increasing in tokens_done (and floored, never negative)."""
    s = Scheduler(policy=oracle_sjf(), max_batch=1)
    r = _req(0, 10)
    r.state = RequestState.RUNNING
    s.running = [r]
    keys = []
    for done in (0, 3, 7, 9, 10, 12):
        r.tokens_done = done
        s.rerank(now=float(done))
        keys.append(s.policy.key(r))
    assert keys == sorted(keys, reverse=True)
    assert keys[0] == 10.0 and keys[-1] == 0.0  # floored at 0
    assert all(k >= 0.0 for k in keys)


def test_sim_run_keys_monotone_between_refreshes():
    """End-to-end: under a per-step rerank cadence, every running request's
    key observed after each step never increases while it stays resident."""
    sched = Scheduler(policy=oracle_sjf(), max_batch=4)
    seen = {}

    def watch(core, now):
        for r in core.scheduler.running:
            seen.setdefault(r.req_id, []).append(core.scheduler.policy.key(r))

    reqs = [_req(i, 5 + 7 * i, arrival=0.1 * i) for i in range(8)]
    fin = simulate(reqs, sched, rerank_every_steps=1, on_step=watch)
    assert len(fin) == 8
    assert seen
    for rid, keys in seen.items():
        assert keys == sorted(keys, reverse=True), rid


def test_without_rerank_behaviour_is_write_once():
    """No cadence configured ⇒ remaining_est never set, keys = arrival
    scores, zero refreshes: the historical write-once contract."""
    sched = Scheduler(policy=oracle_sjf(), max_batch=2)
    fin = simulate([_req(i, 10 + i) for i in range(5)], sched)
    assert sched.rerank_count == 0
    assert all(r.remaining_est is None for r in fin)
    assert all(r.rerank_preemptions is None for r in fin)
    rep = report("x", fin)
    assert math.isnan(rep.reranks) and math.isnan(rep.rerank_preemptions)


# ------------------------------------------------------------ determinism
def _skewed(n=24, seed_gap=0.05):
    reqs = []
    for i in range(n):
        out = 60 if i % 6 == 0 else 4
        r = _req(i, out, arrival=i * seed_gap)
        r.score = float(out)
        r.scored = True
        reqs.append(r)
    return reqs


@pytest.mark.parametrize("rerank_kw", [
    {},                                          # off
    {"rerank_every_steps": 1},
    {"rerank_every_steps": 3},
    {"rerank_interval": 0.4},
])
def test_fixed_trace_schedules_are_deterministic(rerank_kw):
    """Re-rank on or off, a fixed trace reproduces the exact schedule run
    over run (seeded ties: equal keys fall back to arrival order)."""
    def once():
        sched = Scheduler(policy=oracle_sjf(), max_batch=3, preemption=True,
                          max_preemptions=4)
        fin = simulate(_skewed(), sched,
                       cost=CostModel(iter_base_s=0.01, per_seq_s=0.0,
                                      prefill_per_token_s=0.001),
                       **rerank_kw)
        return {r.req_id: (r.start_time, r.first_token_time, r.finish_time,
                           r.preempt_count, r.boosted) for r in fin}
    assert once() == once()


# -------------------------------------------------------- starvation bound
def test_pin_after_demotions_bounds_preemptions():
    """Under a per-step rerank cadence and aggressive preemption, a request
    demoted more than K times is pinned boosted: it stops being a victim
    and its total demotions stay bounded by K+1."""
    K = 2
    long = _req(0, 400, arrival=0.0)
    shorts = [_req(i, 2, arrival=0.2 * i) for i in range(1, 40)]
    sched = Scheduler(policy=oracle_sjf(), max_batch=1, preemption=True,
                      max_preemptions=1000)       # the cap must come from K
    fin = {r.req_id: r for r in simulate(
        [long] + shorts, sched,
        cost=CostModel(iter_base_s=0.01, per_seq_s=0.0,
                       prefill_per_token_s=0.0),
        rerank_every_steps=1, rerank_pin_after=K)}
    assert len(fin) == 40
    assert sched.pin_after_demotions == K         # core installed the bound
    lr = fin[0]
    assert lr.tokens_done == 400
    assert lr.preempt_count + lr.defer_count <= K + 1
    assert lr.boosted                             # it did get pinned


def test_existing_scheduler_pin_setting_wins():
    sched = Scheduler(policy=oracle_sjf(), max_batch=2,
                      pin_after_demotions=7)
    simulate([_req(0, 3)], sched, rerank_every_steps=1, rerank_pin_after=2)
    assert sched.pin_after_demotions == 7         # core must not override


def test_boosted_requests_are_never_preempted():
    s = Scheduler(policy=oracle_sjf(), max_batch=1, preemption=True)
    pinned = _req(0, 1000)
    pinned.state = RequestState.RUNNING
    pinned.boosted = True
    s.running = [pinned]
    s.add_requests([_req(1, 1)])
    s.schedule(0.0)
    assert s.running == [pinned]                  # short stayed waiting


# ------------------------------------------------------------- metrics
def test_rerank_metrics_recorded():
    sched = Scheduler(policy=oracle_sjf(), max_batch=1, preemption=True,
                      max_preemptions=4)
    reqs = [_req(0, 80, arrival=0.0)] + [_req(i, 2, arrival=0.5 + 0.01 * i)
                                         for i in range(1, 6)]
    fin = simulate(reqs, sched,
                   cost=CostModel(iter_base_s=0.01, per_seq_s=0.0,
                                  prefill_per_token_s=0.0),
                   rerank_every_steps=1)
    rep = report("x", fin, reranks=sched.rerank_count)
    assert rep.reranks > 0
    assert rep.rerank_preemptions >= 1            # the eviction was attributed
    assert fin and all(r.rerank_preemptions is not None for r in fin)


# ------------------------------------------------------------- probe
def test_probe_reads_refreshed_estimate_not_stale_score():
    """predicted_remaining_tokens must serve the refreshed remaining_est —
    the router otherwise routes by whatever predicted_len(fallback) says."""
    core = make_sim_replicas(1, oracle_sjf, rerank_every_steps=1)[0]
    r = _req(0, 9, prompt="a b c d e f g h", prompt_len=8)
    r.state = RequestState.RUNNING
    r.prefilled_tokens = 8
    r.prefill_target = 8
    core.scheduler.running = [r]
    stale = core.predicted_remaining_tokens(lambda q: 1000.0)
    assert stale == pytest.approx(1000.0)         # fallback: predicted_len
    r.tokens_done = 4
    core.scheduler.rerank(now=1.0)
    fresh = core.predicted_remaining_tokens(lambda q: 1000.0)
    assert fresh == pytest.approx(9 - 4)          # refreshed, not the 1000


# ------------------------------------------------------ router N=1 parity
def _parity_trace(n=24):
    reqs = []
    for i in range(n):
        prompt = " ".join(f"w{i}t{j}" for j in range(10))
        out = 40 if i % 5 == 0 else 3 + i % 4
        r = Request(i, prompt, 0.07 * i, 10, out)
        r.score = float(out)
        r.scored = True
        reqs.append(r)
    return reqs


def _copy(reqs):
    out = []
    for r in reqs:
        c = Request(r.req_id, r.prompt, r.arrival_time, r.prompt_len,
                    r.true_length)
        c.score, c.scored = r.score, r.scored
        out.append(c)
    return out


def _per_request(finished):
    return {r.req_id: (r.start_time, r.first_token_time, r.finish_time,
                       r.tokens_done, r.preempt_count, r.boosted)
            for r in finished}


def _assert_reports_equal(a, b):
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, float) and math.isnan(va):
            assert isinstance(vb, float) and math.isnan(vb), f.name
        else:
            assert va == vb, (f.name, va, vb)


@pytest.mark.parametrize("routing", ROUTING_POLICIES)
def test_single_replica_parity_with_rerank(routing):
    """ReplicaRouter(n=1) stays bit-identical to a bare core when iterative
    re-ranking (plus preemption it drives) is enabled on both."""
    kw = dict(kv_blocks=64, block_size=16, max_batch=3,
              rerank_every_steps=2, preemption=True)
    trace = _parity_trace()

    def sched():
        return Scheduler(policy=oracle_sjf(), max_batch=3, preemption=True)

    bare_sched = sched()
    bare = simulate(_copy(trace), bare_sched,
                    kv_blocks=64, block_size=16,
                    rerank_every_steps=2)
    router = simulate_replicas(_copy(trace), n_replicas=1,
                               policy_factory=oracle_sjf, routing=routing,
                               **kw)
    assert _per_request(router.finished) == _per_request(bare)
    # router.finished is req_id-sorted; order bare the same way so report
    # means sum in the same order (bit-identical floats, not approx)
    bare.sort(key=lambda r: r.req_id)
    _assert_reports_equal(report("parity", bare),
                          report("parity", router.finished))
    agg = router.report()
    assert agg.aggregate.reranks > 0              # cadence actually fired
