"""Chunked prefill (mixed prefill/decode steps) through the ServingCore.

Covers the exact stall chunking eliminates — a running short request must
keep decoding (and finish) while a co-resident long prompt is still
streaming its prefill — plus preemption of half-prefilled requests, the
core's chunk-planning invariants, and real-engine output equivalence.
"""
import jax
import numpy as np
import pytest

from repro.core.scheduler.policies import fcfs, oracle_sjf
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving import (ServingConfig, ServingCore, VirtualClock,
                           itl_samples)
from repro.serving.simulator import CostModel, SimBackend, simulate


def _cost():
    return CostModel(iter_base_s=0.01, per_seq_s=0.0,
                     prefill_per_token_s=0.001)


# ------------------------------------------------------------- core planning
def test_plan_chunks_packs_whole_fits_and_head_of_line_partial():
    """Whole-fitting requests pack; a partial take only happens as the
    step's *first* chunk (full budget) — mid-pack requests that don't fit
    whole are skipped, keeping dispatch shapes bounded."""
    sched = Scheduler(policy=fcfs(), max_batch=8)
    core = ServingCore(sched, SimBackend(_cost()), clock=VirtualClock(),
                       config=ServingConfig(prefill_chunk_tokens=64))
    reqs = [Request(0, "a", 0.0, 16, 4), Request(1, "b", 0.0, 16, 4),
            Request(2, "c", 0.0, 100, 4), Request(3, "d", 0.0, 32, 4)]
    sched.add_requests(reqs)
    sched.schedule(0.0)
    chunks = core._plan_chunks()
    # 16 + 16 pack whole; 2 (needs 100) is skipped mid-pack; 3 still fits
    assert [(r.req_id, s, e) for r, s, e in chunks] == [
        (0, 0, 16), (1, 0, 16), (3, 0, 32)]
    for r, _s, e in chunks:
        r.prefilled_tokens = e
    # next step: request 2 is head-of-line and takes the full budget,
    # split across as many steps as it needs
    assert [(r.req_id, s, e) for r, s, e in core._plan_chunks()] == [
        (2, 0, 64)]


def test_plan_without_budget_is_prefill_to_completion():
    sched = Scheduler(policy=fcfs(), max_batch=8)
    core = ServingCore(sched, SimBackend(_cost()), clock=VirtualClock())
    sched.add_requests([Request(0, "a", 0.0, 500, 4)])
    sched.schedule(0.0)
    (req, start, end), = core._plan_chunks()
    assert (start, end) == (0, 500)


def test_invalid_chunk_budget_rejected():
    with pytest.raises(ValueError):
        ServingConfig(prefill_chunk_tokens=0)


# ---------------------------------------------- mixed steps (deterministic)
def test_short_request_finishes_before_long_prompt_prefill_completes():
    """VirtualClock + SimBackend: with chunking, a running short request
    keeps decoding through a long prompt's prefill and finishes *before*
    the long prompt emits its first token; unchunked, the monolithic
    prefill iteration stalls it past that point."""
    def reqs():
        return [Request(0, "short", 0.0, 10, 3),
                Request(1, "long", 0.01, 2000, 5)]

    un = {r.req_id: r for r in simulate(
        reqs(), Scheduler(policy=fcfs(), max_batch=4), cost=_cost())}
    ch = {r.req_id: r for r in simulate(
        reqs(), Scheduler(policy=fcfs(), max_batch=4), cost=_cost(),
        prefill_chunk_tokens=100)}

    # the stall chunking eliminates: short outlives the long prefill only
    # in the unchunked run
    assert un[0].finish_time > un[1].first_token_time - 0.011
    assert ch[0].finish_time < ch[1].first_token_time
    assert ch[0].finish_time < un[0].finish_time
    # chunking trades the long prompt's TTFT for everyone else's ITL
    assert ch[1].first_token_time > un[1].first_token_time
    # nobody is dropped or short-changed
    assert all(r.tokens_done == r.true_length for r in ch.values())
    assert ch[1].prefilled_tokens == 2000


def test_chunked_itl_tail_beats_unchunked_under_long_prompt_burst():
    """Gap-based p99 ITL: background decoders see the long-prompt burst as
    one huge inter-token gap unchunked, many small ones chunked."""
    def reqs():
        bg = [Request(i, f"bg{i}", 0.0, 8, 40) for i in range(4)]
        burst = [Request(10 + i, f"long{i}", 0.05, 3000, 4) for i in range(3)]
        return bg + burst

    kw = dict(cost=_cost(), record_token_times=True)
    un = simulate(reqs(), Scheduler(policy=fcfs(), max_batch=8), **kw)
    ch = simulate(reqs(), Scheduler(policy=fcfs(), max_batch=8),
                  prefill_chunk_tokens=150, **kw)
    bg_un = [r for r in un if r.req_id < 10]
    bg_ch = [r for r in ch if r.req_id < 10]
    p99_un = np.percentile(itl_samples(bg_un), 99)
    p99_ch = np.percentile(itl_samples(bg_ch), 99)
    assert p99_ch < 0.5 * p99_un


def test_preemption_of_half_prefilled_request_recovers():
    """A victim evicted mid-prefill loses its partial residency and
    re-prefills from offset 0 to its full target on re-admission."""
    reqs = [Request(0, "long", 0.0, 2000, 5), Request(1, "short", 0.2, 8, 2)]
    sched = Scheduler(policy=oracle_sjf(), max_batch=1, preemption=True)
    fin = {r.req_id: r for r in simulate(reqs, sched, cost=_cost(),
                                         prefill_chunk_tokens=64)}
    long, short = fin[0], fin[1]
    assert long.preempt_count >= 1               # evicted mid-prefill
    assert short.finish_time < long.first_token_time
    assert long.tokens_done == 5                 # still completed fully
    assert long.prefilled_tokens == 2000         # re-prefilled from scratch


def test_half_prefilled_requests_do_not_decode():
    """Step-level invariant: while a long prompt is mid-prefill its
    tokens_done stays 0 even though it sits in the running queue."""
    sched = Scheduler(policy=fcfs(), max_batch=4)
    clock = VirtualClock()
    core = ServingCore(sched, SimBackend(_cost()), clock=clock,
                       config=ServingConfig(prefill_chunk_tokens=50))
    sched.add_requests([Request(0, "long", 0.0, 500, 3),
                        Request(1, "co", 0.0, 10, 2)])
    for _ in range(3):                           # a few mixed steps
        clock.wait_until(core.step(clock.now()))
    long = next(r for r in sched.running if r.req_id == 0)
    assert 0 < long.prefilled_tokens < 500
    assert long.tokens_done == 0 and long.first_token_time is None


def test_kv_reservation_is_full_demand_at_admission():
    """Chunking never splits the KV reservation: blocks for prompt + full
    completion are held from the first chunk on."""
    sched = Scheduler(policy=fcfs(), max_batch=4)
    backend = SimBackend(_cost())
    from repro.serving import BlockAllocator
    alloc = BlockAllocator(total_blocks=1000, block_size=16)
    core = ServingCore(sched, backend, allocator=alloc, clock=VirtualClock(),
                       config=ServingConfig(prefill_chunk_tokens=32))
    req = Request(0, "long", 0.0, 320, 16)       # (320+16)/16 = 21 blocks
    sched.add_requests([req])
    core.step(0.0)
    assert 0 < req.prefilled_tokens < 320
    assert alloc.reserved(0) == 21


# ----------------------------------------------------------- real engine
@pytest.fixture(scope="module")
def real_engine_setup():
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm

    cfg = get_smoke_config("llama3_2_3b").replace(dtype="float32",
                                                  vocab_size=2048)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _real_reqs():
    return [Request(i, " ".join(f"w{i}x{j}" for j in range(3 + 7 * i)),
                    0.0, 8, 4 + i) for i in range(4)]


def test_real_engine_chunked_matches_unchunked_outputs(real_engine_setup):
    """Continuation chunks attend over the resident prefix at the right
    offsets, so greedy outputs are identical chunked vs unchunked."""
    from repro.serving.engine import Engine

    cfg, params = real_engine_setup
    outs = {}
    for chunk in (None, 8):
        eng = Engine(cfg, params, Scheduler(policy=fcfs(), max_batch=4),
                     cache_len=64, prompt_len=32, prefill_chunk_tokens=chunk,
                     record_tokens=True)
        eng.submit(_real_reqs())
        fin = eng.run()
        assert len(fin) == 4
        outs[chunk] = {r.req_id: r.generated_tokens for r in fin}
        if chunk:
            assert eng.backend.extend_dispatches > 0   # chunking really ran
        assert eng.allocator.free_blocks == eng.allocator.total_blocks
    assert outs[None] == outs[8]


def test_real_engine_rejects_chunking_for_recurrent_families(real_engine_setup):
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm
    from repro.serving.engine import Engine

    cfg = get_smoke_config("rwkv6_7b").replace(dtype="float32")
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="attention-family"):
        Engine(cfg, params, Scheduler(policy=fcfs(), max_batch=2),
               cache_len=64, prompt_len=16, prefill_chunk_tokens=8)
