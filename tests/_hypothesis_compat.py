"""Use real ``hypothesis`` when installed; otherwise a tiny deterministic
stand-in so the property tests still exercise the invariants on a clean
environment (satellite fix: a hard import aborted the whole suite).

The stand-in supports exactly what this repo's tests use — ``integers``,
``floats``, ``booleans``, ``lists`` strategies, ``@given(**kwargs)`` and a no-op
``settings`` — and replays a fixed number of seeded random examples. It does
no shrinking; install ``hypothesis`` (requirements-dev.txt) for real
property-based testing.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:
    import random

    _N_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, allow_nan=True, allow_infinity=True):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def lists(elements, min_size=0, max_size=None):
            hi = max_size if max_size is not None else min_size + 10
            return _Strategy(lambda rng: [elements.draw(rng) for _ in
                                          range(rng.randint(min_size, hi))])

    st = _Strategies()

    def given(*pos_strategies, **kw_strategies):
        def deco(fn):
            # no functools.wraps: pytest must see a zero-arg signature, not
            # the wrapped function's parameters (it would hunt for fixtures)
            def wrapper():
                rng = random.Random(0)
                for _ in range(_N_EXAMPLES):
                    drawn_pos = [s.draw(rng) for s in pos_strategies]
                    drawn_kw = {name: s.draw(rng)
                                for name, s in kw_strategies.items()}
                    fn(*drawn_pos, **drawn_kw)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
