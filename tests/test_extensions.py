"""Tests for beyond-paper extensions + analyzer edge cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scheduler.policies import make_policy
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.launch.hlo_cost import analyze_hlo
from repro.serving.simulator import simulate


# ------------------------------------------------------------- PARS+ policy
def _req(i, prompt_len, true_len, score=0.0):
    r = Request(i, f"p{i}", 0.0, prompt_len, true_len)
    r.score = score
    return r


def test_pars_plus_alpha_zero_is_pars():
    pred = lambda ps: [0.0] * len(ps)
    p0 = make_policy("pars+", pred, alpha=0.0)
    reqs = [_req(0, 10, 5, score=2.0), _req(1, 10_000, 5, score=1.0)]
    assert p0.key(reqs[0]) > p0.key(reqs[1])         # prompt_len ignored


def test_pars_plus_prefers_short_prompts_on_ties():
    pred = lambda ps: [0.0] * len(ps)
    p = make_policy("pars+", pred, alpha=0.5)
    a, b = _req(0, 2000, 5, score=1.0), _req(1, 10, 5, score=1.0)
    assert p.key(b) < p.key(a)


def test_pars_plus_schedules_everything():
    pred = lambda ps: [float(len(s)) for s in ps]
    reqs = [Request(i, "x" * (i + 1), 0.0, 4 + i, 3 + i) for i in range(20)]
    sched = Scheduler(policy=make_policy("pars+", pred, alpha=0.3),
                      max_batch=4)
    fin = simulate(reqs, sched)
    assert len(fin) == 20


# ------------------------------------------------------ hlo_cost edge cases
def test_hlo_cost_dus_counts_slice_not_buffer():
    """In-place cache updates must count slice bytes (the §Roofline fix)."""
    def update(cache, x):
        return jax.lax.dynamic_update_slice(cache, x, (0, 0))
    cache = jnp.zeros((4096, 256))
    x = jnp.ones((1, 256))
    # donate the buffer — without donation XLA inserts a (real) full copy
    txt = (jax.jit(update, donate_argnums=(0,))
           .lower(cache, x).compile().as_text())
    cs = analyze_hlo(txt)
    # full buffer = 4 MB; the update slice is 1 KB — accept anything < 10% of
    # the full-buffer interpretation
    assert cs.bytes_written < 0.1 * 4096 * 256 * 4


def test_hlo_cost_collectives_counted():
    import os
    # needs >1 device to produce collectives — skip on 1-device runtime
    if len(jax.devices()) < 2:
        pytest.skip("single device")


# ------------------------------------------------------ engine back-pressure
def test_engine_defers_on_kv_exhaustion():
    from repro.configs import get_smoke_config
    from repro.core.scheduler.policies import fcfs
    from repro.models import transformer as tfm
    from repro.serving import BlockAllocator
    from repro.serving.engine import Engine

    cfg = get_smoke_config("llama3_2_3b").replace(dtype="float32",
                                                  vocab_size=2048)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    sched = Scheduler(policy=fcfs(), max_batch=4)
    # allocator so tight only ~1 request fits at a time
    alloc = BlockAllocator(total_blocks=5, block_size=16)
    eng = Engine(cfg, params, sched, cache_len=64, prompt_len=16,
                 allocator=alloc)
    reqs = [Request(i, f"explain topic{i}", 0.0, 8, 10) for i in range(6)]
    eng.submit(reqs)
    fin = eng.run()
    assert len(fin) == 6                       # back-pressure defers, not drops
    assert all(r.finish_time is not None for r in fin)
    assert alloc.free_blocks == 5              # everything released
