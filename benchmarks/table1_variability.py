"""Paper Table I + Fig. 2: output-length spread across model kinds and the
run-to-run relative variance regime the δ-filter is built on."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.data.synthetic import (EXAMPLE_PROMPTS, MODELS, make_corpus,
                                  sample_lengths)


def run() -> None:
    t0 = time.perf_counter()
    # Table I analogue: fixed low/high-complexity prompts per model kind
    demo = make_corpus("alpaca", 2, seed=0)
    demo.prompts = [EXAMPLE_PROMPTS["Q1"], EXAMPLE_PROMPTS["Q2"]]
    demo.z = np.array([-2.0, 2.6])            # count-like vs prove/derive-like
    print("# Table I analogue — output tokens per (model, prompt)")
    print(f"{'model':8s} {'reasoning':9s} {'Q1 (count)':>12s} {'Q2 (prove)':>12s}")
    for name, prof in MODELS.items():
        L = sample_lengths(demo, name)
        print(f"{name:8s} {str(prof.reasoning):9s} {L[0]:12d} {L[1]:12d}")

    # Fig. 2 analogue: run-to-run relative variance over 30 prompts × 10 runs
    print("\n# Fig. 2 analogue — relative output-length variance, 10 runs")
    c = make_corpus("alpaca", 30, seed=7)
    for name in ("llama", "r1"):
        runs = sample_lengths(c, name, n_runs=10)
        rel = runs.max(0) / runs.min(0) - 1.0
        print(f"{name:8s} median {np.median(rel):5.1%}  p90 "
              f"{np.percentile(rel, 90):5.1%}  max {rel.max():5.1%}")
    us = (time.perf_counter() - t0) * 1e6
    emit("table1_variability", us, "lengths+variance regimes reproduced")


if __name__ == "__main__":
    run()
