"""Chunked-prefill benchmark: decode tail latency under a long-prompt burst.

Scenario (both execution modes): a pool of short background requests is
admitted at t=0 and decodes steadily; a burst of long prompts arrives while
they are mid-generation. Unchunked, the burst's prefill runs to completion
inside one step and every background decode stalls for its whole duration —
a p99 inter-token-latency spike. With ``prefill_chunk_tokens`` set, the
burst streams into the KV cache across many mixed steps and background
decodes keep ticking in between.

Reported per mode (JSON via ``--json``, one ``emit`` CSV row for the repo
convention): background p50/p99 inter-token latency from recorded per-token
gaps, background p99 TTFT, and the burst's mean TTFT (the price chunking
pays). The real-engine comparison also asserts chunked and unchunked runs
generate **identical greedy tokens** — chunk continuation is exact, not an
approximation.

    PYTHONPATH=src python -m benchmarks.chunked_prefill            # full
    PYTHONPATH=src python -m benchmarks.chunked_prefill --smoke --json out.json
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.common import emit, record_serving_bench
from repro.core.scheduler.policies import fcfs
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.config import ServingConfig
from repro.serving.metrics import itl_samples
from repro.serving.simulator import CostModel, simulate

BURST_ID0 = 1000      # req_ids >= this are burst (long-prompt) requests


def _stats(finished):
    """Background ITL percentiles + TTFT split for one run."""
    bg = [r for r in finished if r.req_id < BURST_ID0]
    burst = [r for r in finished if r.req_id >= BURST_ID0]
    itl = itl_samples(bg)
    ttft_bg = np.array([r.first_token_time - r.arrival_time for r in bg])
    ttft_burst = np.array([r.first_token_time - r.arrival_time
                           for r in burst])
    return {
        "n_background": len(bg), "n_burst": len(burst),
        "itl_p50_s": float(np.percentile(itl, 50)),
        "itl_p99_s": float(np.percentile(itl, 99)),
        "itl_max_s": float(itl.max()),
        "ttft_p99_bg_s": float(np.percentile(ttft_bg, 99)),
        "ttft_mean_burst_s": float(ttft_burst.mean()),
    }


def _row(label, s):
    print(f"  {label:10s} itl p50={s['itl_p50_s'] * 1e3:8.2f} ms  "
          f"p99={s['itl_p99_s'] * 1e3:8.2f} ms  "
          f"max={s['itl_max_s'] * 1e3:8.2f} ms  "
          f"burst ttft={s['ttft_mean_burst_s']:6.2f} s")


# ---------------------------------------------------------------- simulator
def run_sim(*, n_bg: int = 8, bg_len: int = 80, n_burst: int = 4,
            burst_prompt: int = 4000, chunk: int = 256) -> dict:
    """Discrete-event comparison (A100-scale cost constants).

    ``bg_len`` is sized so the unchunked burst stall (one giant gap per
    background request) sits inside the p99 of its ~``bg_len`` gaps."""
    def reqs():
        bg = [Request(i, f"bg{i}", 0.0, 8, bg_len) for i in range(n_bg)]
        burst = [Request(BURST_ID0 + i, f"long{i}", 1.0, burst_prompt, 8)
                 for i in range(n_burst)]
        return bg + burst

    out = {"chunk_tokens": chunk}
    for label, c in (("unchunked", None), ("chunked", chunk)):
        fin = simulate(reqs(), Scheduler(policy=fcfs(), max_batch=32),
                       cost=CostModel(),
                       config=ServingConfig(prefill_chunk_tokens=c,
                                            record_token_times=True))
        assert len(fin) == n_bg + n_burst
        out[label] = _stats(fin)
        _row(label, out[label])
    return out


# -------------------------------------------------------------- real engine
def run_real(*, arch: str = "llama3_2_3b", n_bg: int = 3, bg_len: int = 60,
             n_burst: int = 6, chunk: int = 16, prompt_len: int = 128,
             burst_at_token: int = 10) -> dict:
    """Wall-clock comparison on the jitted engine (smoke-scale model).

    The burst's arrival is calibrated from the measured decode rate so the
    background requests are mid-generation when the long prompts land,
    regardless of host speed. Runs unchunked and chunked over identical
    request sets and asserts the generated tokens match token-for-token.
    """
    import jax
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm
    from repro.serving.engine import Engine

    cfg = get_smoke_config(arch).replace(dtype="float32", vocab_size=2048)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)

    def reqs(burst_t):
        bg = [Request(i, f"short prompt {i}", 0.0, 4, bg_len)
              for i in range(n_bg)]
        burst = [Request(BURST_ID0 + i,
                         " ".join(f"w{i}x{j}" for j in range(prompt_len - 2)),
                         burst_t, prompt_len, 6) for i in range(n_burst)]
        return bg + burst

    def engine(c):
        eng = Engine(cfg, params,
                     Scheduler(policy=fcfs(), max_batch=n_bg + n_burst),
                     cache_len=2 * prompt_len + 2 * bg_len,
                     prompt_len=prompt_len, record_tokens=True,
                     config=ServingConfig(prefill_chunk_tokens=c,
                                          record_token_times=True))
        eng.warmup()
        return eng

    # calibrate decode seconds/token on this host so the burst lands while
    # the background requests are mid-decode; the unchunked engine is
    # reused for its comparison run afterwards (greedy sampling, so the
    # advanced RNG key cannot change its outputs)
    engines = {"unchunked": engine(None), "chunked": engine(chunk)}
    cal = engines["unchunked"]
    cal.submit([Request(0, "calibration", 0.0, 4, 30)])
    cal_fin = cal.run()[0]
    s_per_tok = (cal_fin.finish_time - cal_fin.first_token_time) / 29
    cal.core.finished.clear()
    burst_t = burst_at_token * s_per_tok
    print(f"  [real] decode ≈ {s_per_tok * 1e3:.2f} ms/token → "
          f"burst at t={burst_t * 1e3:.1f} ms")

    out = {"chunk_tokens": chunk}
    tokens = {}
    for label, eng in engines.items():
        eng.submit(reqs(burst_t))
        fin = eng.run()
        assert len(fin) == n_bg + n_burst
        tokens[label] = {r.req_id: r.generated_tokens for r in fin}
        out[label] = _stats(fin)
        out[label]["extend_dispatches"] = eng.backend.extend_dispatches
        _row(label, out[label])
    out["identical_outputs"] = tokens["unchunked"] == tokens["chunked"]
    assert out["identical_outputs"], "chunked decode diverged from unchunked"
    print("  [real] chunked outputs identical to unchunked ✓")
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: just prove both modes run and "
                         "emit TTFT + ITL percentiles")
    ap.add_argument("--json", default=None, help="write results to this path")
    ap.add_argument("--mode", choices=("sim", "real", "both"), default="both")
    ap.add_argument("--chunk", type=int, default=None,
                    help="override prefill_chunk_tokens in both modes")
    args = ap.parse_args(argv)

    results = {}
    if args.mode in ("sim", "both"):
        print("simulator (A100-scale constants):")
        kw = (dict(n_bg=4, bg_len=60, n_burst=2) if args.smoke else {})
        if args.chunk:
            kw["chunk"] = args.chunk
        results["sim"] = run_sim(**kw)
    if args.mode in ("real", "both"):
        print("real engine (smoke-scale model, wall clock):")
        kw = (dict(n_bg=2, bg_len=40, n_burst=2, prompt_len=32, chunk=8)
              if args.smoke else {})
        if args.chunk:
            kw["chunk"] = args.chunk
        results["real"] = run_real(**kw)

    for mode, res in results.items():
        # CI smoke contract: both latency axes present in both variants
        for variant in ("unchunked", "chunked"):
            assert {"itl_p50_s", "itl_p99_s", "ttft_p99_bg_s",
                    "ttft_mean_burst_s"} <= set(res[variant])
        speedup = res["unchunked"]["itl_p99_s"] / res["chunked"]["itl_p99_s"]
        emit(f"chunked_prefill_{mode}", res["chunked"]["itl_p99_s"] * 1e6,
             f"p99 ITL {speedup:.1f}x lower than unchunked "
             f"(chunk={res['chunk_tokens']})")
    if "sim" in results:
        s = results["sim"]
        record_serving_bench("chunked_prefill", {
            "p99_itl_speedup": s["unchunked"]["itl_p99_s"]
            / s["chunked"]["itl_p99_s"],
            "chunked_p99_itl_s": s["chunked"]["itl_p99_s"],
            "unchunked_p99_itl_s": s["unchunked"]["itl_p99_s"],
            "chunk_tokens": s["chunk_tokens"],
        })
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return results


if __name__ == "__main__":
    main()
