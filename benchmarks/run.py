"""Benchmark driver — one function per paper table/figure (deliverable (d)).

    PYTHONPATH=src python -m benchmarks.run            # fast mode
    REPRO_BENCH_FULL=1 PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --only table2 scheduling

Each benchmark prints its table and a ``name,us_per_call,derived`` CSV row.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = ("table1", "table2", "table3", "table4", "scheduling",
           "cross_model", "pars_plus", "starvation", "kernels", "roofline",
           "prefill_admission")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {BENCHES}")
    args = ap.parse_args()
    selected = args.only or BENCHES

    from benchmarks import (cross_model, kernel_bench, pars_plus_ablation,
                            prefill_admission, roofline, scheduling_latency,
                            starvation_sweep, table1_variability,
                            table2_rank_methods, table3_backbones,
                            table4_filtering)
    runners = {
        "table1": table1_variability.run,
        "table2": table2_rank_methods.run,
        "table3": table3_backbones.run,
        "table4": table4_filtering.run,
        "scheduling": scheduling_latency.run,
        "cross_model": cross_model.run,
        "pars_plus": pars_plus_ablation.run,
        "starvation": starvation_sweep.run,
        "kernels": kernel_bench.run,
        "roofline": roofline.run,
        "prefill_admission": prefill_admission.run,
    }
    t0 = time.perf_counter()
    failures = []
    for name in selected:
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}", flush=True)
        try:
            runners[name]()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    print(f"\ntotal benchmark wall time: {time.perf_counter() - t0:.0f}s")
    if failures:
        print(f"FAILED benches: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
