"""Benchmark driver — one function per paper table/figure (deliverable (d)).

    PYTHONPATH=src python -m benchmarks.run            # fast mode
    REPRO_BENCH_FULL=1 PYTHONPATH=src python -m benchmarks.run
    PYTHONPATH=src python -m benchmarks.run --only table2 scheduling

Each benchmark prints its table and a ``name,us_per_call,derived`` CSV row.

Serving benchmarks (``SERVING_BENCHES`` in :mod:`benchmarks.common`) are
enumerated uniformly: each exposes a ``main(argv)`` built on
:func:`benchmarks.common.bench_main`, so the driver invokes them the same
way the CLI does. The old standalone ``scheduling``/``starvation`` entries
are now scenarios of the workload harness and remap accordingly.
"""
from __future__ import annotations

import argparse
import functools
import importlib
import sys
import time
import traceback

from benchmarks.common import SERVING_BENCHES

BENCHES = ("table1", "table2", "table3", "table4", "scheduling",
           "cross_model", "pars_plus", "starvation", "kernels", "roofline",
           "prefill_admission") + SERVING_BENCHES


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {BENCHES}")
    ap.add_argument("--smoke", action="store_true",
                    help="pass --smoke through to the serving benchmarks")
    args = ap.parse_args()
    selected = args.only or BENCHES

    from benchmarks import (cross_model, kernel_bench, pars_plus_ablation,
                            prefill_admission, roofline, table1_variability,
                            table2_rank_methods, table3_backbones,
                            table4_filtering, workload_harness)
    serving_argv = ["--smoke"] if args.smoke else []
    runners = {
        "table1": table1_variability.run,
        "table2": table2_rank_methods.run,
        "table3": table3_backbones.run,
        "table4": table4_filtering.run,
        # folded into the workload harness (ISSUE 10): same paper sections,
        # now driven by the declarative trace generator
        "scheduling": functools.partial(
            workload_harness.main, [*serving_argv, "--scenario",
                                    "rate_sweep"]),
        "cross_model": cross_model.run,
        "pars_plus": pars_plus_ablation.run,
        "starvation": functools.partial(
            workload_harness.main, [*serving_argv, "--scenario",
                                    "starvation"]),
        "kernels": kernel_bench.run,
        "roofline": roofline.run,
        "prefill_admission": prefill_admission.run,
    }
    for bench_name in SERVING_BENCHES:
        mod = importlib.import_module(f"benchmarks.{bench_name}")
        runners[bench_name] = functools.partial(mod.main, list(serving_argv))

    t0 = time.perf_counter()
    failures = []
    for name in selected:
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}", flush=True)
        try:
            runners[name]()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    print(f"\ntotal benchmark wall time: {time.perf_counter() - t0:.0f}s")
    if failures:
        print(f"FAILED benches: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
