"""Paged-decode benchmark: admitted concurrency under a fixed KV budget.

Full-demand reservation admits a request only when its *entire* KV demand
(prompt + max completion) fits — most of that reservation sits empty while
the request decodes its way toward it. Incremental reservation (the paged-KV
admission policy, ``kv_reservation="incremental"``) admits on prompt + one
decode block and grows the block table one step ahead of decode, so the same
budget holds roughly ``full_demand / prompt_demand`` times more concurrent
requests; when a grow is denied, the core preempts the lowest-ranked running
request (recompute semantics) and the denied request proceeds.

Three sections:

* **sim** — discrete-event run on the shared ServingCore: peak admitted
  concurrency full vs incremental at the same ``kv_blocks`` budget. Asserts
  the ISSUE acceptance bar — **>= 1.5x** — and, on a tighter budget, that
  grow-failure preemption fires and every request still finishes (recovery
  without deadlock), with the grow counters surfaced in ``report()``.
* **real** — the jitted paged engine: greedy outputs bit-identical paged vs
  contiguous, zero KV tokens copied on the prefix-cache hit path, and the
  grow/preempt counters live end to end.
* **kernel** — ``flash_decode_paged`` vs its jnp oracle on a GQA shape with
  shuffled + aliased tables (parity, plus a wall-clock row).

    PYTHONPATH=src python -m benchmarks.paged_decode            # full
    PYTHONPATH=src python -m benchmarks.paged_decode --smoke --json out.json
"""
from __future__ import annotations

import argparse
import json
import math
import time

import numpy as np

from benchmarks.common import emit, record_serving_bench
from repro.core.scheduler.policies import fcfs
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.config import ServingConfig
from repro.serving.metrics import report
from repro.serving.simulator import CostModel, simulate


# ---------------------------------------------------------------- simulator
def run_sim(*, n: int = 12, prompt_len: int = 16, out_len: int = 48,
            kv_blocks: int = 16, block_size: int = 16,
            tight_blocks: int = 6) -> dict:
    """Peak-concurrency comparison at a fixed budget, then a deliberately
    tight budget to exercise grow-failure preemption and recovery."""

    def reqs():
        return [Request(i, f"req {i} " + " ".join(f"w{j}" for j in range(8)),
                        0.0, prompt_len, out_len) for i in range(n)]

    def run(reservation, blocks):
        peak = {"running": 0}

        def probe(core, _now):
            peak["running"] = max(peak["running"],
                                  len(core.scheduler.running))

        fin = simulate(reqs(), Scheduler(policy=fcfs(), max_batch=n),
                       cost=CostModel(), kv_blocks=blocks,
                       block_size=block_size, on_step=probe,
                       config=ServingConfig(kv_reservation=reservation))
        assert len(fin) == n, "requests lost — scheduler deadlocked?"
        assert all(r.tokens_done == r.true_length for r in fin)
        return fin, peak["running"]

    out = {"kv_blocks": kv_blocks, "n_requests": n,
           "kv_demand_blocks_per_req": math.ceil((prompt_len + out_len)
                                                 / block_size)}
    for label in ("full", "incremental"):
        fin, peak = run(label, kv_blocks)
        rep = report("fcfs", fin)
        out[label] = {
            "peak_concurrency": peak,
            "makespan_s": rep.makespan,
            "avg_ttft_s": rep.avg_ttft,
            "grow_failures": rep.grow_failures,
            "grow_preemptions": rep.grow_preemptions,
        }
        print(f"  [sim] {label:11s} peak_concurrency={peak:3d}  "
              f"makespan={rep.makespan:7.2f} s  "
              f"grow_failures={rep.grow_failures}")
    # reservation-mode metrics contract: counters exist exactly when the
    # run reserved incrementally (NaN-safe aggregation otherwise)
    assert math.isnan(out["full"]["grow_failures"])
    assert not math.isnan(out["incremental"]["grow_failures"])
    ratio = (out["incremental"]["peak_concurrency"]
             / out["full"]["peak_concurrency"])
    out["concurrency_ratio"] = ratio
    assert ratio >= 1.5, f"admitted-concurrency ratio {ratio:.2f}x < 1.5x"
    print(f"  [sim] incremental admits {ratio:.1f}x more concurrent "
          f"requests at the same budget")

    # tight budget: growth *must* fail; preemption recovers, nothing hangs
    fin, _ = run("incremental", tight_blocks)
    rep = report("fcfs", fin)
    out["tight_budget"] = {
        "kv_blocks": tight_blocks,
        "grow_failures": rep.grow_failures,
        "grow_preemptions": rep.grow_preemptions,
        "preempted_requests": sum(1 for r in fin if r.preempt_count),
    }
    assert rep.grow_failures > 0, "tight budget never denied a grow"
    assert rep.grow_preemptions > 0, "denials never forced a preemption"
    print(f"  [sim] tight budget ({tight_blocks} blocks): "
          f"{rep.grow_failures:.0f} grow failures, "
          f"{rep.grow_preemptions:.0f} preemptions, all {n} finished")
    return out


# -------------------------------------------------------------- real engine
def run_real(*, arch: str = "llama3_2_3b", shared_words: int = 24,
             n_warm: int = 3, out_len: int = 4, prompt_len: int = 32,
             n_tight: int = 5, tight_out: int = 40) -> dict:
    """Paged engine smoke: bit-identity vs contiguous on a shared-prefix
    workload (zero-copy hits), then grow/preempt recovery end to end."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm
    from repro.serving.engine import Engine
    from repro.serving.kv_cache import BlockAllocator

    cfg = get_smoke_config(arch).replace(dtype="float32", vocab_size=2048)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    prefix = " ".join(f"sys{i}" for i in range(shared_words))

    def shared_run(paged):
        eng = Engine(cfg, params,
                     Scheduler(policy=fcfs(), max_batch=n_warm + 1),
                     cache_len=2 * prompt_len, prompt_len=prompt_len,
                     paged=paged, record_tokens=True,
                     config=ServingConfig(prefix_caching=True))
        eng.submit([Request(0, prefix + " donor tail", 0.0, prompt_len,
                            out_len)])
        eng.run()
        eng.submit([Request(10 + i, prefix + f" user{i} suffix", 0.0,
                            prompt_len, out_len) for i in range(n_warm)])
        eng.run()
        assert len(eng.finished) == n_warm + 1
        return eng

    out = {}
    t0 = time.perf_counter()
    contig = shared_run(False)
    paged = shared_run(True)
    out["wall_s"] = time.perf_counter() - t0
    toks = {p: {r.req_id: r.generated_tokens for r in e.finished}
            for p, e in (("contiguous", contig), ("paged", paged))}
    out["identical_outputs"] = toks["contiguous"] == toks["paged"]
    assert out["identical_outputs"], "paged decode diverged from contiguous"
    out["prefix_installs"] = paged.backend.prefix_installs
    out["prefix_tokens_copied"] = paged.backend.prefix_tokens_copied
    assert out["prefix_installs"] == n_warm
    assert out["prefix_tokens_copied"] == 0, "paged hit path copied KV"
    print(f"  [real] paged outputs identical to contiguous; "
          f"{n_warm} zero-copy prefix hits (0 tokens copied)")

    # incremental + tight budget on the real engine: recovery, live counters.
    # 14-word prompts land in the 16-token bucket, so demand = 16 +
    # tight_out tokens >= 3 blocks/request while admission reserves prompt
    # + one decode block = 2 — the rest *must* come from decode-time grows,
    # and 6 total blocks can't grow everyone at once
    reqs = [Request(i, f"r{i} " + " ".join(f"w{j}" for j in range(13)), 0.0,
                    16, tight_out) for i in range(n_tight)]
    eng = Engine(cfg, params, Scheduler(policy=fcfs(), max_batch=n_tight),
                 cache_len=48, prompt_len=16, allocator=BlockAllocator(6, 16),
                 record_tokens=True,
                 config=ServingConfig(kv_reservation="incremental"))
    eng.submit(reqs)
    fin = eng.run()
    assert len(fin) == n_tight
    assert all(r.tokens_done == r.true_length for r in fin)
    rep = report("fcfs", fin)
    out["tight_budget"] = {"grow_failures": rep.grow_failures,
                           "grow_preemptions": rep.grow_preemptions}
    assert rep.grow_failures > 0 and rep.grow_preemptions > 0
    print(f"  [real] tight budget: {rep.grow_failures:.0f} grow failures, "
          f"{rep.grow_preemptions:.0f} preemptions, all requests finished")
    return out


# ------------------------------------------------------------------ kernel
def run_kernel(*, b: int = 4, h: int = 8, kh: int = 2, bs: int = 16,
               mb: int = 8, dh: int = 64, iters: int = 20) -> dict:
    """Paged Pallas kernel vs jnp oracle on shuffled + aliased tables."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_decode.ops import paged_decode_attention_pallas
    from repro.kernels.flash_decode.ref import flash_decode_paged_ref

    n_blocks = 2 * b * mb
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    k_pool = jax.random.normal(ks[0], (n_blocks, kh, bs, dh))
    v_pool = jax.random.normal(ks[1], (n_blocks, kh, bs, dh))
    q = jax.random.normal(ks[2], (b, h, dh))
    rng = np.random.default_rng(0)
    tables = np.stack([rng.permutation(n_blocks)[:mb] for _ in range(b)])
    tables[:, 0] = 0                              # aliased shared block
    tables = jnp.asarray(tables, jnp.int32)
    lengths = jnp.asarray([mb * bs - (11 * i) % (mb * bs - 1)
                           for i in range(b)], jnp.int32)

    out = paged_decode_attention_pallas(q, k_pool, v_pool, tables, lengths)
    ref = flash_decode_paged_ref(q.reshape(b, kh, h // kh, dh), k_pool,
                                 v_pool, tables, lengths).reshape(b, h, dh)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 2e-5, f"paged kernel off oracle by {err}"
    t0 = time.perf_counter()
    for _ in range(iters):
        out = paged_decode_attention_pallas(q, k_pool, v_pool, tables,
                                            lengths)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / iters * 1e6
    print(f"  [kernel] paged decode parity max|err|={err:.2e}, "
          f"{us:.1f} us/call (interpret-mode on CPU)")
    return {"max_abs_err": err, "us_per_call": us,
            "shape": dict(b=b, h=h, kh=kh, block_size=bs, max_blocks=mb,
                          dh=dh)}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: prove the concurrency bar, "
                         "recovery, zero-copy hits, and kernel parity")
    ap.add_argument("--json", default=None, help="write results to this path")
    ap.add_argument("--mode", choices=("sim", "real", "kernel", "all"),
                    default="all")
    args = ap.parse_args(argv)

    results = {}
    if args.mode in ("sim", "all"):
        print("simulator (A100-scale constants):")
        kw = dict(n=8, out_len=32, kv_blocks=12, tight_blocks=5) \
            if args.smoke else {}
        results["sim"] = run_sim(**kw)
    if args.mode in ("real", "all"):
        print("real engine (smoke-scale model, wall clock):")
        kw = dict(shared_words=16, n_warm=2, prompt_len=32, n_tight=4) \
            if args.smoke else {}
        results["real"] = run_real(**kw)
    if args.mode in ("kernel", "all"):
        print("paged Pallas kernel:")
        kw = dict(b=2, mb=4, iters=5) if args.smoke else {}
        results["kernel"] = run_kernel(**kw)

    if "sim" in results:
        s = results["sim"]
        emit("paged_decode_sim", s["incremental"]["avg_ttft_s"] * 1e6,
             f"incremental reservation holds "
             f"{s['concurrency_ratio']:.1f}x more concurrent requests at "
             f"{s['kv_blocks']} KV blocks; "
             f"{s['tight_budget']['grow_preemptions']:.0f} grow-preemptions "
             f"recovered on the tight budget")
        record_serving_bench("paged_decode", {
            "concurrency_ratio": s["concurrency_ratio"],
            "peak_concurrency_full": s["full"]["peak_concurrency"],
            "peak_concurrency_incremental":
                s["incremental"]["peak_concurrency"],
            "tight_budget_grow_failures":
                s["tight_budget"]["grow_failures"],
            "tight_budget_grow_preemptions":
                s["tight_budget"]["grow_preemptions"],
            "real_prefix_tokens_copied":
                results.get("real", {}).get("prefix_tokens_copied"),
            "real_identical_outputs":
                results.get("real", {}).get("identical_outputs"),
        })
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return results


if __name__ == "__main__":
    main()
