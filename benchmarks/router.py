"""Multi-replica routing benchmark: policy sweep over the two regimes the
router's metric policies target.

**Affinity trace** (shared-system-prompt families): F prompt families, each
with a long common prefix, interleaved so consecutive arrivals come from
different families. Per-replica KV budgets hold only a couple of family
prefixes, so ``round_robin`` sprays every family across every replica and
churns each LRU, while ``prefix_affinity`` pins each family to the replica
already holding its committed blocks. Acceptance bar (ISSUE): affinity
achieves **>= 2x the aggregate warm hit rate** of round-robin (warm = every
request after its family's first — the cold miss that populates a cache is
excluded in both policies).

**Skewed-output trace** (predictor-aware dispatch): mostly-short responses
with a heavy-tailed long minority, ``Request.score`` pre-annotated with the
true output length (a perfect PARS predictor stand-in — the routing analogue
of the paper's oracle bound). ``round_robin`` keeps assigning to replicas
already stuck behind long decodes; ``predicted_shortest_queue`` dispatches
by predicted remaining work. Acceptance bar: PSQ's **mean routed TTFT is
lower** than round-robin's.

Every policy in ``ROUTING_POLICIES`` runs on both traces (fresh replicas per
run; identical traces per policy). Costs are the simulator's A100-scale
constants; traces are sized to finish in ~1–2 min — ``--requests N`` scales
either trace up (the discrete-event core sweeps ~10^5-request traces in
minutes), ``--smoke`` shrinks both for CI.

    PYTHONPATH=src python -m benchmarks.router                 # full
    PYTHONPATH=src python -m benchmarks.router --smoke --json out.json
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ServingBench, bench_main
from repro.core.scheduler.policies import fcfs
from repro.core.scheduler.request import Request
from repro.serving.config import ServingConfig
from repro.serving.router import ROUTING_POLICIES
from repro.serving.simulator import simulate_replicas


def affinity_trace(n: int = 4000, *, families: int = 8,
                   shared_words: int = 96, unique_words: int = 8,
                   out_len: int = 8, gap_s: float = 0.06,
                   seed: int = 0):
    """Family-interleaved shared-prefix stream (see module docstring)."""
    rng = np.random.default_rng(seed)
    fams = rng.permutation(np.repeat(np.arange(families),
                                     -(-n // families))[:n])
    prompt_len = 1 + shared_words + unique_words        # CLS + words
    reqs = []
    for i, fam in enumerate(fams):
        prompt = (" ".join(f"f{fam}s{k}" for k in range(shared_words))
                  + " " + " ".join(f"u{i}w{j}" for j in range(unique_words)))
        r = Request(i, prompt, i * gap_s, prompt_len, out_len)
        r.score = float(out_len)
        reqs.append(r)
    return reqs


def skew_trace(n: int = 3000, *, prompt_words: int = 16, short: int = 8,
               long: int = 200, p_long: float = 0.15, rate_hz: float = 8.0,
               seed: int = 0):
    """Poisson arrivals, bimodal output lengths, oracle-scored requests."""
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    outs = rng.choice([short, long], size=n, p=[1 - p_long, p_long])
    reqs = []
    for i in range(n):
        prompt = " ".join(f"q{i}w{j}" for j in range(prompt_words))
        r = Request(i, prompt, float(t[i]), 1 + prompt_words, int(outs[i]))
        r.score = float(outs[i])                       # perfect predictor
        reqs.append(r)
    return reqs


def _warm_hit_rate(router, trace) -> float:
    """Hit rate over warm requests only: the first arrival of each prompt
    family is the unavoidable cold miss and is excluded."""
    first_of_family = {}
    for r in sorted(trace, key=lambda r: (r.arrival_time, r.req_id)):
        # all members of a family share the same first prompt word (f"f{fam}s0")
        first_of_family.setdefault(r.prompt.split(" ", 1)[0], r.req_id)
    cold = set(first_of_family.values())
    warm = [r for r in router.finished if r.req_id not in cold]
    hits = [1.0 if (r.cached_prefix_tokens or 0) > 0 else 0.0 for r in warm]
    return float(np.mean(hits)) if hits else float("nan")


def _sweep(trace_fn, *, n_replicas: int, label: str, warm_hits: bool,
           **replica_kw) -> dict:
    out = {}
    print(f"{label} ({n_replicas} replicas):")
    for routing in ROUTING_POLICIES:
        trace = trace_fn()
        router = simulate_replicas(trace, n_replicas=n_replicas,
                                   policy_factory=fcfs, routing=routing,
                                   seed=0, **replica_kw)
        assert len(router.finished) == len(trace)
        rep = router.report()
        out[routing] = {
            "ttft_mean_s": rep.routed_ttft_mean_s,
            "ttft_p99_s": rep.routed_ttft_p99_s,
            "hit_rate": rep.cross_replica_hit_rate,
            "load_imbalance": rep.load_imbalance,
            "requests_per_replica": list(rep.requests_per_replica),
            "throughput_tok_s": rep.aggregate.throughput_tok_s,
        }
        if warm_hits:
            out[routing]["warm_hit_rate"] = _warm_hit_rate(router, trace)
        print("  " + rep.row())
    return out


def run_affinity(*, n: int = 4000, n_replicas: int = 4) -> dict:
    out = _sweep(lambda: affinity_trace(n), n_replicas=n_replicas,
                 label="affinity trace", warm_hits=True,
                 kv_blocks=24, block_size=16, max_batch=4,
                 config=ServingConfig(prefix_caching=True))
    ratio = (out["prefix_affinity"]["warm_hit_rate"]
             / max(out["round_robin"]["warm_hit_rate"], 1e-9))
    out["warm_hit_rate_gain"] = ratio
    # ISSUE acceptance bar: affinity routing >= 2x round-robin's warm hit rate
    assert out["prefix_affinity"]["warm_hit_rate"] \
        >= 2.0 * out["round_robin"]["warm_hit_rate"], \
        f"affinity warm hit-rate gain {ratio:.2f}x < 2x"
    print(f"  [affinity] warm hit rate {ratio:.1f}x round_robin "
          f"({out['prefix_affinity']['warm_hit_rate']:.2f} vs "
          f"{out['round_robin']['warm_hit_rate']:.2f})")
    return out


def run_skew(*, n: int = 3000, n_replicas: int = 3) -> dict:
    out = _sweep(lambda: skew_trace(n), n_replicas=n_replicas,
                 label="skewed-output trace", warm_hits=False,
                 kv_blocks=64, block_size=16, max_batch=4)
    win = (out["round_robin"]["ttft_mean_s"]
           / out["predicted_shortest_queue"]["ttft_mean_s"])
    out["psq_ttft_speedup"] = win
    # ISSUE acceptance bar: predictor-aware dispatch lowers mean routed TTFT
    assert out["predicted_shortest_queue"]["ttft_mean_s"] \
        < out["round_robin"]["ttft_mean_s"], \
        f"PSQ mean TTFT not below round_robin ({win:.2f}x)"
    print(f"  [skew] PSQ mean TTFT {win:.2f}x lower than round_robin "
          f"({out['predicted_shortest_queue']['ttft_mean_s'] * 1e3:.1f} ms "
          f"vs {out['round_robin']['ttft_mean_s'] * 1e3:.1f} ms)")
    return out


def _run(args) -> dict:
    results = {}
    if args.mode in ("affinity", "both"):
        results["affinity"] = run_affinity(
            n=args.requests or (240 if args.smoke else 4000))
    if args.mode in ("skew", "both"):
        results["skew"] = run_skew(
            n=args.requests or (240 if args.smoke else 3000))
    return results


def _headline(results) -> list:
    rows = []
    if "affinity" in results:
        a = results["affinity"]
        rows.append(("router_affinity",
                     a["prefix_affinity"]["ttft_mean_s"] * 1e6,
                     f"warm hit rate {a['warm_hit_rate_gain']:.1f}x "
                     f"round_robin "
                     f"({a['prefix_affinity']['warm_hit_rate']:.2f} vs "
                     f"{a['round_robin']['warm_hit_rate']:.2f})"))
    if "skew" in results:
        s = results["skew"]
        rows.append(("router_skew",
                     s["predicted_shortest_queue"]["ttft_mean_s"] * 1e6,
                     f"PSQ mean TTFT {s['psq_ttft_speedup']:.2f}x lower "
                     f"than round_robin"))
    return rows


def _add_args(ap) -> None:
    ap.add_argument("--requests", type=int, default=None,
                    help="override trace length for both regimes")
    ap.add_argument("--mode", choices=("affinity", "skew", "both"),
                    default="both")


BENCH = ServingBench(
    name="router",
    run=_run,
    section=lambda results: {
        k: {
            "warm_hit_rate_gain": v.get("warm_hit_rate_gain"),
            "psq_ttft_speedup": v.get("psq_ttft_speedup"),
            "policies": {p: v[p] for p in ROUTING_POLICIES if p in v},
        } for k, v in results.items()
    },
    headline=_headline,
    add_args=_add_args,
    smoke_help="tiny CI config: prove the sweep runs and both acceptance "
               "bars hold",
)


def main(argv=None) -> dict:
    return bench_main(BENCH, argv)


if __name__ == "__main__":
    main()
