"""Paper Table IV: min_length_difference filtering ablation (δ on/off)."""
from __future__ import annotations

import time

from benchmarks.common import FAST, emit, get_predictor, tau_of
from repro.data.synthetic import DATASETS, MODELS


def run() -> dict:
    combos = ([("alpaca", "gpt4"), ("alpaca", "r1"), ("lmsys", "llama")]
              if FAST else [(d, m) for d in DATASETS for m in MODELS])
    print("# Table IV analogue — tau_b with / without delta filtering")
    print(f"{'dataset':8s} {'model':6s} | {'without':>8s} {'with':>8s} {'delta':>6s}")
    results = {}
    t0 = time.perf_counter()
    for ds, m in combos:
        d = MODELS[m].delta
        without = tau_of(get_predictor(ds, m, delta=0.0), ds, m)
        with_f = tau_of(get_predictor(ds, m, delta=d), ds, m)
        results[(ds, m)] = (without, with_f)
        print(f"{ds:8s} {m:6s} | {without:8.3f} {with_f:8.3f} {d:6.2f}")
    us = (time.perf_counter() - t0) * 1e6
    gains = sum(1 for w, f in results.values() if f >= w - 0.01)
    emit("table4_filtering", us,
         f"filtering helps-or-ties in {gains}/{len(results)} combos")
    return results


if __name__ == "__main__":
    run()
