"""Prefix-caching benchmark: TTFT/throughput under shared-system-prompt load.

Scenario (both execution modes): a stream of requests that all begin with
the same long system prompt (the dominant real-traffic sharing pattern:
assistant preambles, few-shot templates, reasoning scaffolds) followed by a
short unique user suffix. Without caching every request re-prefills the
whole prompt — a fixed TTFT floor of ``prefill_per_token x prompt`` that no
scheduling policy can remove. With ``prefix_caching=True`` the first
request's prompt blocks are committed to the refcounted cache and every
later admission reserves only the unique suffix, starts chunked prefill at
the cached offset, and reaches its first token after suffix-only work.

Reported per mode (JSON via ``--json``, one ``emit`` CSV row for the repo
convention): mean/p99 TTFT of the shared-prefix (warm) requests with caching
off vs on, prefix hit rate, prefill tokens saved, and throughput. The sim
comparison asserts the ISSUE acceptance bar — **>= 2x lower mean TTFT** for
shared-prefix requests — and the real-engine comparison asserts greedy
outputs are **bit-identical** with caching on vs off (KV reuse is exact, not
an approximation).

    PYTHONPATH=src python -m benchmarks.prefix_caching            # full
    PYTHONPATH=src python -m benchmarks.prefix_caching --smoke --json out.json
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.common import emit, record_serving_bench
from repro.core.scheduler.policies import fcfs
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.config import ServingConfig
from repro.serving.metrics import report
from repro.serving.simulator import CostModel, simulate


def _stats(finished, core=None):
    """Warm-request TTFT split + cache counters for one run. The cold-start
    request (earliest arrival) is excluded from the warm set — it is the
    miss that populates the cache in both variants."""
    cold = min(finished, key=lambda r: r.arrival_time)
    warm = [r for r in finished if r is not cold]
    ttft = np.array([r.first_token_time - r.arrival_time for r in warm])
    rep = report("fcfs", finished)
    return {
        "n_requests": len(finished),
        "ttft_mean_warm_s": float(ttft.mean()),
        "ttft_p99_warm_s": float(np.percentile(ttft, 99)),
        "ttft_cold_s": float(cold.first_token_time - cold.arrival_time),
        "prefix_hit_rate": float(rep.prefix_hit_rate),
        "prefill_tokens_saved": float(rep.prefill_tokens_saved),
        "throughput_tok_s": rep.throughput_tok_s,
    }


def _row(label, s):
    print(f"  {label:10s} warm ttft mean={s['ttft_mean_warm_s'] * 1e3:8.2f} ms"
          f"  p99={s['ttft_p99_warm_s'] * 1e3:8.2f} ms  "
          f"hit_rate={s['prefix_hit_rate']:5.2f}  "
          f"saved={s['prefill_tokens_saved']:9.0f} tok  "
          f"tput={s['throughput_tok_s']:8.1f} tok/s")


# ---------------------------------------------------------------- simulator
def run_sim(*, n: int = 32, shared_words: int = 1024, unique_words: int = 63,
            out_len: int = 32, gap_s: float = 0.7) -> dict:
    """Discrete-event comparison (A100-scale cost constants). Arrivals are
    spaced so each prompt's prefill commits before the next admission — the
    steady-state regime where every request after the first is a hit."""
    prompt_len = 1 + shared_words + unique_words        # CLS + words
    prefix = " ".join(f"sys{i}" for i in range(shared_words))

    def reqs():
        return [Request(i, prefix + " " +
                        " ".join(f"u{i}w{j}" for j in range(unique_words)),
                        i * gap_s, prompt_len, out_len) for i in range(n)]

    out = {"shared_prompt_tokens": shared_words}
    for label, caching in (("uncached", False), ("cached", True)):
        fin = simulate(reqs(), Scheduler(policy=fcfs(), max_batch=8),
                       cost=CostModel(),
                       config=ServingConfig(prefix_caching=caching))
        assert len(fin) == n
        out[label] = _stats(fin)
        _row(label, out[label])
    speedup = (out["uncached"]["ttft_mean_warm_s"]
               / out["cached"]["ttft_mean_warm_s"])
    out["warm_ttft_speedup"] = speedup
    # the ISSUE acceptance bar: >= 2x lower mean TTFT for shared-prefix
    # requests in sim mode
    assert speedup >= 2.0, f"warm-TTFT speedup {speedup:.2f}x < 2x"
    print(f"  [sim] warm mean TTFT {speedup:.1f}x lower with prefix caching")
    return out


# -------------------------------------------------------------- real engine
def run_real(*, arch: str = "llama3_2_3b", n_warm: int = 6,
             shared_words: int = 40, unique_words: int = 8,
             prompt_len: int = 64, out_len: int = 6) -> dict:
    """Wall-clock comparison on the jitted engine (smoke-scale model).

    Two-phase submits (donor first, then the warm cohort) make the hit
    pattern deterministic regardless of host speed. Asserts token-for-token
    identical greedy outputs cached vs uncached."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import transformer as tfm
    from repro.serving.engine import Engine

    cfg = get_smoke_config(arch).replace(dtype="float32", vocab_size=2048)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    prefix = " ".join(f"sys{i}" for i in range(shared_words))
    wc = 1 + shared_words + unique_words

    def run(caching):
        eng = Engine(cfg, params,
                     Scheduler(policy=fcfs(), max_batch=n_warm + 1),
                     cache_len=2 * prompt_len, prompt_len=prompt_len,
                     record_tokens=True,
                     config=ServingConfig(prefix_caching=caching))
        eng.warmup()
        eng.submit([Request(0, prefix + " donor tail words", 0.0, wc,
                            out_len)])
        eng.run()
        eng.submit([Request(10 + i, prefix + " " +
                            " ".join(f"u{i}w{j}" for j in range(unique_words)),
                            0.0, wc, out_len) for i in range(n_warm)])
        eng.run()
        assert len(eng.finished) == n_warm + 1
        return eng

    out = {"shared_words": shared_words}
    tokens = {}
    for label, caching in (("uncached", False), ("cached", True)):
        eng = run(caching)
        tokens[label] = {r.req_id: r.generated_tokens for r in eng.finished}
        out[label] = _stats(eng.finished)
        out[label]["prefix_installs"] = eng.backend.prefix_installs
        out[label]["prefix_tokens_copied"] = eng.backend.prefix_tokens_copied
        _row(label, out[label])
    out["identical_outputs"] = tokens["uncached"] == tokens["cached"]
    assert out["identical_outputs"], "cached decode diverged from uncached"
    assert out["cached"]["prefix_installs"] == n_warm
    print("  [real] cached outputs identical to uncached ✓")
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: prove both modes run, the sim "
                         "speedup holds, and real outputs match")
    ap.add_argument("--json", default=None, help="write results to this path")
    ap.add_argument("--mode", choices=("sim", "real", "both"), default="both")
    args = ap.parse_args(argv)

    results = {}
    if args.mode in ("sim", "both"):
        print("simulator (A100-scale constants):")
        kw = (dict(n=8, shared_words=512, unique_words=31) if args.smoke
              else {})
        results["sim"] = run_sim(**kw)
    if args.mode in ("real", "both"):
        print("real engine (smoke-scale model, wall clock):")
        kw = (dict(n_warm=3, shared_words=20, unique_words=6, prompt_len=32)
              if args.smoke else {})
        results["real"] = run_real(**kw)

    for mode, res in results.items():
        # CI smoke contract: the cache counters and both TTFT axes exist
        for variant in ("uncached", "cached"):
            assert {"ttft_mean_warm_s", "ttft_p99_warm_s", "prefix_hit_rate",
                    "prefill_tokens_saved"} <= set(res[variant])
        if mode == "sim":
            speedup = (res["uncached"]["ttft_mean_warm_s"]
                       / res["cached"]["ttft_mean_warm_s"])
            derived = (f"warm-request mean TTFT {speedup:.1f}x lower than "
                       f"uncached "
                       f"(hit_rate={res['cached']['prefix_hit_rate']:.2f})")
        else:
            # the smoke-scale model is too small for prefill compute to
            # dominate wall TTFT; the real-engine row reports what it
            # *asserts* — exact KV reuse — plus the accounting
            derived = (f"outputs identical cached vs uncached; "
                       f"{res['cached']['prefill_tokens_saved']:.0f} prefill "
                       f"tokens saved "
                       f"(hit_rate={res['cached']['prefix_hit_rate']:.2f})")
        emit(f"prefix_caching_{mode}", res["cached"]["ttft_mean_warm_s"] * 1e6,
             derived)
    if "sim" in results:
        s = results["sim"]
        record_serving_bench("prefix_caching", {
            "warm_ttft_speedup": s["warm_ttft_speedup"],
            "cached_warm_ttft_s": s["cached"]["ttft_mean_warm_s"],
            "uncached_warm_ttft_s": s["uncached"]["ttft_mean_warm_s"],
            "hit_rate": s["cached"]["prefix_hit_rate"],
            "prefill_tokens_saved": s["cached"]["prefill_tokens_saved"],
        })
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return results


if __name__ == "__main__":
    main()
