"""Pallas kernel microbenchmarks (interpret mode — functional timing only on
CPU; the BlockSpec/VMEM structure is the TPU deliverable, see kernels/*)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels.flash_decode.ops import decode_attention_pallas
from repro.kernels.flash_prefill.ops import flash_attention
from repro.kernels.rwkv6_chunk.ops import linear_attention_pallas
from repro.models.attention import attention_chunked, decode_attention


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> None:
    key = jax.random.PRNGKey(0)
    print("# kernel microbench (CPU interpret mode) — name,us_per_call,derived")

    # flash prefill vs XLA chunked reference
    b, h, kh, s, dh = 1, 8, 2, 512, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, s, dh), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, kh, s, dh), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, kh, s, dh), jnp.bfloat16)
    us = _time(lambda *a: flash_attention(*a), q, k, v)
    emit("flash_prefill_pallas_interp_b1h8s512", us,
         f"{2 * 2 * b * h * s * s * dh / (us / 1e6) / 1e9:.2f}GFLOP/s-equiv")
    qb = q.transpose(0, 2, 1, 3)
    kb = k.transpose(0, 2, 1, 3)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    us = _time(lambda: attention_chunked(qb, kb, v.transpose(0, 2, 1, 3),
                                         pos, pos))
    emit("flash_prefill_xla_chunked_b1h8s512", us, "XLA twin")

    # decode over 8k cache
    w = 8192
    q1 = jax.random.normal(ks[0], (4, 8, 128), jnp.bfloat16)
    kc = jax.random.normal(ks[1], (4, w, 2, 128), jnp.bfloat16)
    vc = jax.random.normal(ks[2], (4, w, 2, 128), jnp.bfloat16)
    us = _time(lambda: decode_attention_pallas(q1, kc, vc, w - 1))
    emit("flash_decode_pallas_interp_b4w8192", us, "ring-masked")
    us = _time(lambda: decode_attention(q1, kc, vc, w - 1))
    emit("flash_decode_xla_b4w8192", us, "XLA twin")

    # rwkv6 chunked
    q2 = jax.random.normal(ks[0], (1, 8, 1024, 64))
    k2 = jax.random.normal(ks[1], (1, 8, 1024, 64))
    v2 = jax.random.normal(ks[2], (1, 8, 1024, 64))
    lw = -jax.nn.sigmoid(jax.random.normal(ks[0], (1, 8, 1024, 64)))
    u = jnp.zeros((8, 64))
    us = _time(lambda: linear_attention_pallas(q2, k2, v2, lw, u))
    emit("rwkv6_chunk_pallas_interp_t1024", us, "chunk=64")


if __name__ == "__main__":
    run()
