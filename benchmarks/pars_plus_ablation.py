"""Beyond-paper ablation: PARS+ (prefill-aware SJF) vs PARS.

The paper ranks only by expected decode length. In mixed workloads where a
fraction of requests carry long (RAG/document) prompts, admission pays a
prefill cost ∝ prompt_len that pure PARS ignores. PARS+ adds
α·log1p(prompt_len) to the ranking key (α=0 ≡ PARS).

Workload: alpaca/llama burst with 20% of requests given 100× prompt length
(≈2k prefill tokens at the simulator's 0.5 ms/token prefill cost).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import corpus, emit, get_predictor, lengths, scale
from repro.core.scheduler.policies import fcfs, make_policy, oracle_sjf
from repro.data.workload import burst_arrivals, make_requests
from repro.serving.simulator import run_policy


def run() -> dict:
    sc = scale()
    rng = np.random.default_rng(11)
    pred = get_predictor("alpaca", "llama", method="pairwise")
    c, L = corpus("alpaca", "test"), lengths("alpaca", "test", "llama")
    n = sc.burst
    idx = rng.integers(0, len(c.prompts), n)
    base = make_requests(c, L, burst_arrivals(n), indices=idx)
    long_mask = rng.random(n) < 0.2
    for r, is_long in zip(base, long_mask):
        if is_long:
            r.prompt_len *= 100                     # RAG-style document prompt

    print("# PARS+ ablation — 20% long-prompt burst, n =", n)
    results = {}
    t0 = time.perf_counter()
    score_std = float(np.std(pred.score([c.prompts[j] for j in idx[:256]])))
    policies = [("fcfs", fcfs()), ("pars", make_policy("pars", pred))]
    for alpha in (0.25, 0.5, 1.0):
        policies.append((f"pars+a{alpha}", make_policy(
            "pars+", pred, alpha=alpha, score_scale=max(score_std, 1e-6))))
    policies.append(("oracle", oracle_sjf()))
    for name, pol in policies:
        reqs = [type(r)(r.req_id, r.prompt, r.arrival_time, r.prompt_len,
                        r.true_length) for r in base]
        from repro.core.scheduler.scheduler import Scheduler
        from repro.serving.simulator import simulate
        from repro.serving.metrics import report
        sched = Scheduler(policy=pol, max_batch=16)
        fin = simulate(reqs, sched)
        rep = report(name, fin)
        results[name] = rep
        print("  " + rep.row())
    gain = (results["pars"].avg_per_token_latency
            / min(results[k].avg_per_token_latency
                  for k in results if k.startswith("pars+")))
    print(f"  => best PARS+ vs PARS: {gain:.2f}x")
    emit("pars_plus_ablation", (time.perf_counter() - t0) * 1e6,
         f"prefill-aware ranking gains {gain:.2f}x on long-prompt mix")
    return results


if __name__ == "__main__":
    run()
