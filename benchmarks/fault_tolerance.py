"""Chaos benchmark: the serving stack's fault-tolerance acceptance bars.

Four sections, every one deterministic under its fixed seed (virtual sim
clock, seeded fault schedules, a table-lookup scorer stand-in — no wall
clock, no predictor training):

* **crash_failover** — a 3-replica routed run under scheduled replica
  crashes + cold restarts, against the *same trace* fault-free. Acceptance:
  request conservation across crash/restart (every submitted request is
  finished or terminally dropped, never lost or duplicated), at least one
  failover re-dispatch absorbed, and **bounded p99 TTFT inflation** vs the
  fault-free baseline (crashes cost recompute, not collapse).
* **predictor_degradation** — a scorer outage mid-run on a predictor-SJF
  core. Acceptance: the policy **degrades to FCFS then recovers** (both
  counters advance, and the run ends un-degraded), with every request
  served.
* **deadline_shed** — an overload burst against per-request deadlines and
  the sustained-pressure shedding gate. Acceptance: the overload is resolved
  by *counted terminal drops* (deadline cancels + sheds), and everything
  else finishes.
* **no_fault_parity** — a run with an **empty** ``FaultSchedule`` attached
  must be bit-identical (per-request start / first-token / finish
  timestamps, and per-request routing) to a run with no schedule at all:
  the fault layer's hooks are free when unconfigured.

    PYTHONPATH=src python -m benchmarks.fault_tolerance           # full
    PYTHONPATH=src python -m benchmarks.fault_tolerance --smoke --json out.json
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ServingBench, bench_main
from repro.core.scheduler.policies import fcfs, predictor_sjf
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.config import ServingConfig
from repro.serving.faults import FaultSchedule, ReplicaCrash, ScorerOutage
from repro.serving.simulator import (clone_requests, make_sim_core,
                                     make_sim_replicas, simulate_replicas)
from repro.serving.metrics import RunCounters, report
from repro.serving.router import ReplicaRouter

# Faulty p99 TTFT may cost at most this factor over fault-free. Full-scale
# traces measure ~1.0x (crashes are a small fraction of the run); the smoke
# trace is short enough that two crashes + restarts overlap a large share of
# it, so the bound is sized for that worst case.
P99_INFLATION_BOUND = 8.0


def poisson_trace(n: int, *, rate_hz: float = 6.0, prompt_words: int = 12,
                  short: int = 8, long: int = 64, p_long: float = 0.2,
                  seed: int = 0):
    """Poisson arrivals, bimodal output lengths — the stack's standard
    mixed decode workload, small enough that a smoke run finishes in
    seconds."""
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    outs = rng.choice([short, long], size=n, p=[1 - p_long, p_long])
    return [Request(i, " ".join(f"q{i}w{j}" for j in range(prompt_words)),
                    float(t[i]), 1 + prompt_words, int(outs[i]))
            for i in range(n)]


# fresh Request objects so one run's mutations never leak into the next
# (deadlines carry over — they are workload, not run state)
_clone = clone_requests


def _table_scorer(reqs):
    """Perfect-predictor stand-in: score every prompt with its request's
    true output length (no model training in a chaos smoke run)."""
    table = {r.prompt: float(r.true_length) for r in reqs}
    return lambda prompts: [table[p] for p in prompts]


def _assert_conserved(router, trace) -> None:
    retired = sorted(r.req_id for r in
                     [*router.finished, *router.all_dropped])
    assert retired == sorted(r.req_id for r in trace), \
        "request lost or duplicated across crash/restart"


# ----------------------------------------------------------- crash failover
def run_crash_failover(*, n: int = 1200, n_replicas: int = 3) -> dict:
    trace = poisson_trace(n, seed=1)
    kw = dict(n_replicas=n_replicas, policy_factory=fcfs,
              routing="least_kv_pressure", seed=0,
              kv_blocks=96, block_size=16, max_batch=4)

    base = simulate_replicas(_clone(trace), **kw)
    assert len(base.finished) == n
    base_p99 = base.report().routed_ttft_p99_s

    faults = FaultSchedule(crashes=(
        ReplicaCrash(replica=0, at_step=20, down_events=60),
        ReplicaCrash(replica=1, at_step=max(n // 3, 40), down_events=60),
    ))
    faulty = simulate_replicas(_clone(trace), faults=faults,
                               failover_backoff_s=0.05, **kw)
    _assert_conserved(faulty, trace)
    assert faults.injected_crashes >= 2, "scheduled crashes never fired"
    assert faulty.redispatches >= 1, "no failover re-dispatch absorbed"
    rep = faulty.report()
    inflation = rep.routed_ttft_p99_s / max(base_p99, 1e-9)
    assert inflation <= P99_INFLATION_BOUND, \
        f"p99 TTFT inflation {inflation:.2f}x exceeds " \
        f"{P99_INFLATION_BOUND}x under 2 crashes"
    out = {
        "n_requests": n,
        "n_replicas": n_replicas,
        "injected_crashes": faults.injected_crashes,
        "crashes_per_replica": list(rep.crashes),
        "restarts_per_replica": list(rep.restarts),
        "failover_redispatches": rep.failover_redispatches,
        "dropped_total": rep.aggregate.dropped_total,
        "baseline_p99_ttft_s": base_p99,
        "faulty_p99_ttft_s": rep.routed_ttft_p99_s,
        "p99_ttft_inflation": inflation,
        "p99_ttft_inflation_bound": P99_INFLATION_BOUND,
    }
    print(f"  [crash] {faults.injected_crashes} crashes, "
          f"{int(sum(rep.restarts))} restarts, "
          f"{int(rep.failover_redispatches)} redispatches; p99 TTFT "
          f"{rep.routed_ttft_p99_s * 1e3:.1f} ms vs {base_p99 * 1e3:.1f} ms "
          f"fault-free ({inflation:.2f}x <= {P99_INFLATION_BOUND}x)")
    return out


# --------------------------------------------------- predictor degradation
def run_predictor_degradation(*, n: int = 600) -> dict:
    trace = poisson_trace(n, seed=2)
    faults = FaultSchedule(scorer_outages=(
        ScorerOutage(first_call=3, n_calls=4),))
    pol = predictor_sjf("pars", faults.wrap_scorer(_table_scorer(trace)),
                        scorer_failure_budget=2, recovery_probe_every=1)
    core = make_sim_core(Scheduler(policy=pol, max_batch=4),
                         kv_blocks=96, block_size=16)
    faults.attach_core(core)
    core.submit(_clone(trace))
    finished = core.run()
    assert len(finished) + len(core.dropped) == n
    assert faults.injected_scorer_faults >= 4, "scorer outage never fired"
    assert pol.degradations >= 1, "failure budget never degraded the policy"
    assert pol.recoveries >= 1, "the policy never recovered from FCFS"
    assert not pol.degraded, "run ended still degraded"
    rep = report("pars", finished, counters=RunCounters(
        dropped=tuple(core.dropped),
        scorer_failures=pol.scorer_failures,
        degradations=pol.degradations, recoveries=pol.recoveries))
    out = {
        "n_requests": n,
        "scorer_failures": rep.scorer_failures,
        "degradations": rep.predictor_degradations,
        "recoveries": rep.predictor_recoveries,
        "avg_per_token_latency_s": rep.avg_per_token_latency,
        "p99_ttft_s": rep.p99_ttft,
    }
    print(f"  [degrade] {int(rep.scorer_failures)} scorer failures -> "
          f"{int(rep.predictor_degradations)} degradation(s), "
          f"{int(rep.predictor_recoveries)} recovery(ies); all {n} served")
    return out


# ----------------------------------------------------------- deadline/shed
def run_deadline_shed(*, n: int = 400) -> dict:
    # an instantaneous burst: everything arrives at t=0 against a
    # max_batch=2 core, so queue depth stays far above the shed threshold
    trace = poisson_trace(n, rate_hz=1e9, seed=3)
    for r in trace:                 # tight-but-feasible SLO for short work;
        r.deadline = r.arrival_time + (3.0 if r.true_length <= 8 else 1e6)
    core = make_sim_core(Scheduler(policy=fcfs(), max_batch=2),
                         kv_blocks=96, block_size=16,
                         config=ServingConfig(
                             deadline_time_per_token=0.03,
                             shed_queue_depth=max(n // 4, 8),
                             shed_sustain_steps=3))
    core.submit(_clone(trace))
    finished = core.run()
    assert len(finished) + len(core.dropped) == n
    rep = report("fcfs", finished, counters=RunCounters.from_core(core))
    assert rep.dropped_total >= 1, "overload burst produced no drops"
    assert rep.shed >= 1, "sustained overload never shed the tail"
    out = {
        "n_requests": n,
        "finished": len(finished),
        "deadline_cancelled": rep.deadline_cancelled,
        "shed": rep.shed,
        "dropped_total": rep.dropped_total,
    }
    print(f"  [shed] burst of {n}: {len(finished)} finished, "
          f"{int(rep.deadline_cancelled)} deadline-cancelled, "
          f"{int(rep.shed)} shed")
    return out


# --------------------------------------------------------- no-fault parity
def _sig(router) -> list:
    """Bit-level run signature: per-request timing and placement."""
    return sorted((r.req_id, router.assignments[r.req_id], r.start_time,
                   r.first_token_time, r.finish_time)
                  for r in router.finished)


def run_no_fault_parity(*, n: int = 300, n_replicas: int = 2) -> dict:
    trace = poisson_trace(n, seed=4)
    kw = dict(kv_blocks=64, block_size=16, max_batch=4)

    def routed(schedule):
        cores = make_sim_replicas(n_replicas, fcfs, **kw)
        router = ReplicaRouter(cores, policy="round_robin", seed=0)
        if schedule is not None:
            reqs = _clone(trace)
            schedule.skew_arrivals(reqs)
            schedule.attach_router(router)
        else:
            reqs = _clone(trace)
        router.submit(reqs)
        router.run()
        return _sig(router)

    plain, empty = routed(None), routed(FaultSchedule())
    assert plain == empty, \
        "empty FaultSchedule changed behaviour: fault hooks are not free"
    print(f"  [parity] empty schedule bit-identical over {n} requests "
          f"x {n_replicas} replicas")
    return {"n_requests": n, "identical": True}


def _run(args) -> dict:
    print("chaos benchmark" + (" (smoke)" if args.smoke else "") + ":")
    return {
        "crash_failover": run_crash_failover(n=150 if args.smoke else 1200),
        "predictor_degradation":
            run_predictor_degradation(n=120 if args.smoke else 600),
        "deadline_shed": run_deadline_shed(n=80 if args.smoke else 400),
        "no_fault_parity":
            run_no_fault_parity(n=60 if args.smoke else 300),
    }


def _headline(results) -> list:
    cf = results["crash_failover"]
    dg = results["predictor_degradation"]
    return [
        ("fault_crash_failover", cf["faulty_p99_ttft_s"] * 1e6,
         f"p99 TTFT {cf['p99_ttft_inflation']:.2f}x fault-free under "
         f"{cf['injected_crashes']} crashes; conservation held"),
        ("fault_predictor_degradation", dg["p99_ttft_s"] * 1e6,
         f"{int(dg['degradations'])} degradation(s) + "
         f"{int(dg['recoveries'])} recovery(ies) across "
         f"{int(dg['scorer_failures'])} scorer failures"),
    ]


BENCH = ServingBench(
    name="fault_tolerance",
    run=_run,
    section=lambda results: results,
    headline=_headline,
    smoke_help="tiny CI config: prove every acceptance bar holds",
)


def main(argv=None) -> dict:
    return bench_main(BENCH, argv)


if __name__ == "__main__":
    main()
