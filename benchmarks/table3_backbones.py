"""Paper Table III: backbone comparison (T5 / OPT / BERT) under pairwise
training."""
from __future__ import annotations

import time

from benchmarks.common import FAST, emit, get_predictor, tau_of
from repro.core.predictor import BACKBONES
from repro.data.synthetic import DATASETS, MODELS


def run() -> dict:
    combos = ([("alpaca", "gpt4"), ("alpaca", "r1"), ("lmsys", "llama")]
              if FAST else [(d, m) for d in DATASETS for m in MODELS])
    print("# Table III analogue — tau_b by backbone (pairwise training)")
    print(f"{'dataset':8s} {'model':6s} | {'t5':>7s} {'opt':>7s} {'bert':>7s}")
    results = {}
    t0 = time.perf_counter()
    for ds, m in combos:
        row = {}
        for bb in ("t5", "opt", "bert"):
            row[bb] = tau_of(get_predictor(ds, m, backbone=bb), ds, m)
        results[(ds, m)] = row
        print(f"{ds:8s} {m:6s} | {row['t5']:7.3f} {row['opt']:7.3f} "
              f"{row['bert']:7.3f}")
    us = (time.perf_counter() - t0) * 1e6
    emit("table3_backbones", us,
         "pairwise effective across all three backbones")
    return results


if __name__ == "__main__":
    run()
