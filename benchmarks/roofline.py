"""§Roofline deliverable: aggregate dry-run JSON artifacts into the
per-(arch × shape × mesh) roofline table (terms in seconds, bottleneck,
MODEL_FLOPS ratio). Artifacts come from:

    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
        --out results/dryrun_baseline
"""
from __future__ import annotations

import glob
import json
import os
import time

from benchmarks.common import emit

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun_baseline")


def load(dirpath: str = DEFAULT_DIR):
    rows = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def run(dirpath: str = DEFAULT_DIR) -> None:
    t0 = time.perf_counter()
    rows = load(dirpath)
    if not rows:
        print(f"# roofline: no dry-run artifacts in {dirpath} — run "
              "repro.launch.dryrun first")
        emit("roofline", 0.0, "no artifacts")
        return
    print("# Roofline table (derived from compiled dry-run artifacts)")
    print(f"{'arch':22s} {'shape':12s} {'mesh':10s} {'compute':>10s} "
          f"{'memory':>10s} {'collective':>11s} {'bottleneck':>11s} "
          f"{'useful':>7s} {'GB/dev':>8s}")
    counts = {}
    for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        if r.get("skipped"):
            continue
        counts[r["bottleneck"]] = counts.get(r["bottleneck"], 0) + 1
        gb = r.get("memory_gb_per_device")
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:10s} "
              f"{r['t_compute'] * 1e3:9.2f}ms {r['t_memory'] * 1e3:9.2f}ms "
              f"{r['t_collective'] * 1e3:10.2f}ms {r['bottleneck']:>11s} "
              f"{r['useful_fraction']:7.1%} "
              f"{gb if gb is None else round(gb, 1):>8}")
    us = (time.perf_counter() - t0) * 1e6
    emit("roofline", us, f"{len(rows)} combos; bottlenecks={counts}")


if __name__ == "__main__":
    run()
