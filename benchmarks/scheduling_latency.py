"""Paper §IV-D: scheduling efficiency — avg & p90 per-token latency across
arrival rates, plus the 2000-request burst, for all five policies
(FCFS / Pointwise / Listwise / PARS / Oracle)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import corpus, emit, get_predictor, lengths, scale
from repro.core.scheduler.policies import fcfs, make_policy, oracle_sjf
from repro.data.workload import burst_arrivals, make_requests, poisson_arrivals
from repro.serving.simulator import run_policy

POLICIES = ("fcfs", "pointwise", "listwise", "pars", "oracle")
# paper's four (dataset, model) evaluation combos
COMBOS = (("alpaca", "llama"), ("alpaca", "r1"),
          ("lmsys", "llama"), ("lmsys", "r1"))


def _policy(name, ds, m):
    if name == "fcfs":
        return fcfs()
    if name == "oracle":
        return oracle_sjf()
    method = {"pars": "pairwise", "pointwise": "pointwise",
              "listwise": "listwise"}[name]
    return make_policy(name, get_predictor(ds, m, method=method))


def _requests(ds, m, arrivals, rng):
    c = corpus(ds, "test")
    L = lengths(ds, "test", m)
    idx = rng.integers(0, len(c.prompts), len(arrivals))
    return make_requests(c, L, arrivals, indices=idx)


def run(combos=COMBOS, rates=(0.5, 1.0, 2.0), max_batch: int = 16) -> dict:
    sc = scale()
    rng = np.random.default_rng(0)
    results = {}
    t0 = time.perf_counter()
    for ds, m in combos:
        # --- arrival-rate sweep ---------------------------------------------
        # reasoning outputs are ~20× longer; scale rates so the queue is
        # stressed-but-stable in both regimes (the paper tunes rates per model)
        rscale = 0.05 if m == "r1" else 1.0
        for rate in rates:
            arr = poisson_arrivals(sc.sweep_requests, rate * rscale, seed=1)
            print(f"\n# {ds}/{m} poisson rate={rate * rscale:g} req/s "
                  f"n={sc.sweep_requests}")
            for pol in POLICIES:
                rep = run_policy(_requests(ds, m, arr, rng), _policy(pol, ds, m),
                                 max_batch=max_batch)
                results[(ds, m, rate, pol)] = rep
                print("  " + rep.row())
        # --- burst ------------------------------------------------------------
        arr = burst_arrivals(sc.burst)
        print(f"\n# {ds}/{m} BURST n={sc.burst}")
        for pol in POLICIES:
            rep = run_policy(_requests(ds, m, arr, rng), _policy(pol, ds, m),
                             max_batch=max_batch)
            results[(ds, m, "burst", pol)] = rep
            print("  " + rep.row())
        f = results[(ds, m, "burst", "fcfs")].avg_per_token_latency
        p = results[(ds, m, "burst", "pars")].avg_per_token_latency
        print(f"  => burst speedup PARS vs FCFS: {f / p:.2f}x")
    us = (time.perf_counter() - t0) * 1e6
    sp = [results[(ds, m, 'burst', 'fcfs')].avg_per_token_latency
          / results[(ds, m, 'burst', 'pars')].avg_per_token_latency
          for ds, m in combos]
    emit("scheduling_latency", us,
         f"burst speedups PARS/FCFS: {['%.1fx' % s for s in sp]}")
    return results


if __name__ == "__main__":
    run()
