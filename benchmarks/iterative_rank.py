"""Iterative re-ranking benchmark: static PARS vs remaining-length SRPT
under the real ServingCore, with a mispredict-robustness sweep.

**Skewed-output trace.** A minority of long responses inside a steady
short/medium stream, preemption on. Static PARS ranks a request by its
predicted *total* length forever: a long request that is 90% decoded still
keys as "long", so every medium arrival preempts it (recompute semantics —
the victim re-prefills prompt *plus* everything it had generated, and that
prefill burst stalls the whole co-resident batch). Iterative re-ranking
refreshes keys to ``max(score − tokens_done, floor)`` on a step cadence:
once a long request's remaining work undercuts the arrivals, it stops being
a victim, finishes, and frees its batch slot. Acceptance bars (ISSUE):

* iterative mean latency >= 1.2x better than static PARS, and
* iterative p99 latency strictly better than static PARS.

**Mispredict-robustness sweep.** Scores carry multiplicative lognormal
noise, ``score = true_len * exp(sigma * N(0, 1))``, one shared noise
realization per sigma so every rank method sees identical predictions.
The sigma axis subsumes the Table-II rank-method comparison: sigma=0 is
the oracle ranker, and each trained method (listwise / pointwise / PARS
pairwise) corresponds to some effective noise level — sweeping sigma
shows how both scheduling modes respond to the *whole* predictor-quality
range rather than three points on it.
Acceptance bar: at the heaviest noise level, iterative degrades no worse
than FCFS (the predictor-free fallback) on mean latency — the
pin-after-K-demotions starvation bound is what keeps noise-churned ranks
from thrashing a request forever.

Everything runs through ``simulate()``, i.e. the same ``ServingCore`` step
loop and ``Scheduler`` the real JAX engine drives — only the backend clock
is virtual.

    PYTHONPATH=src python -m benchmarks.iterative_rank            # full
    PYTHONPATH=src python -m benchmarks.iterative_rank --smoke --json out.json
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ServingBench, bench_main
from repro.core.scheduler.policies import fcfs, predictor_sjf
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.config import ServingConfig
from repro.serving.metrics import RunCounters, report
from repro.serving.simulator import CostModel, simulate

# recompute-heavy regime: preemption is cheap to trigger and expensive to
# pay for, which is exactly where total-length vs remaining-length ranking
# diverges (see module docstring)
COST = CostModel(iter_base_s=0.01, per_seq_s=0.0005,
                 prefill_per_token_s=0.002)
MAX_BATCH = 4
MAX_PREEMPTIONS = 10
RERANK_EVERY_STEPS = 2
PIN_AFTER = 3
NOISE_SIGMAS = (0.0, 0.3, 0.7, 1.2)


def skewed_trace(n: int, *, seed: int = 0, rate_hz: float = 10.0,
                 prompt_words: int = 24):
    """Poisson arrivals; 10% long (240 tok) / 30% medium (48) / 60% short
    (8) outputs. Returns (requests, true_lengths) — scores are attached per
    noise level by :func:`annotate_scores`."""
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    outs = rng.choice([240, 48, 8], size=n, p=[0.10, 0.30, 0.60])
    reqs = []
    for i in range(n):
        prompt = " ".join(f"q{i}w{j}" for j in range(prompt_words))
        reqs.append(Request(i, prompt, float(t[i]), 1 + prompt_words,
                            int(outs[i])))
    return reqs


def noise_factors(n: int, sigma: float, *, seed: int = 7) -> np.ndarray:
    """One lognormal mispredict realization, shared by every rank method at
    a given sigma (fair comparison: same predictions, different use)."""
    if sigma == 0.0:
        return np.ones(n)
    rng = np.random.default_rng(seed)
    return np.exp(rng.normal(0.0, sigma, n))


def annotate_scores(reqs, factors) -> None:
    """Pre-annotate noisy predictor scores (``scored`` set, so the policy's
    batched arrival scoring is skipped — the predictor is simulated)."""
    for r, f in zip(reqs, factors):
        r.score = float(r.true_length) * float(f)
        r.scored = True


def _fresh(reqs):
    out = []
    for r in reqs:
        c = Request(r.req_id, r.prompt, r.arrival_time, r.prompt_len,
                    r.true_length)
        c.score, c.scored = r.score, r.scored
        out.append(c)
    return out


def run_method(reqs, method: str) -> dict:
    """One rank method over one (already score-annotated) trace, preemption
    on for every method so the only variable is *how requests are ranked*:

    * ``fcfs``       — arrival order, scores ignored
    * ``static``     — PARS keys frozen at the arrival score
    * ``iterative``  — same scores, refreshed to remaining length on a
      2-step cadence, starvation-bounded by pinning
    """
    reqs = _fresh(reqs)
    policy = fcfs() if method == "fcfs" else predictor_sjf("pars", None)
    # wall-clock starvation boosting is disabled so the comparison isolates
    # the rank methods themselves (boosted requests rank FIFO, which would
    # blur static vs iterative at saturation); the demotion-count pin bound
    # is the starvation mechanism under test for the iterative method
    sched = Scheduler(policy=policy, max_batch=MAX_BATCH, preemption=True,
                      max_preemptions=MAX_PREEMPTIONS,
                      starvation_threshold=float("inf"))
    cfg = (ServingConfig(rerank_every_steps=RERANK_EVERY_STEPS,
                         rerank_pin_after=PIN_AFTER)
           if method == "iterative" else ServingConfig())
    fin = simulate(reqs, sched, cost=COST, config=cfg)
    assert len(fin) == len(reqs), (method, len(fin), len(reqs))
    e2e = np.array([r.finish_time - r.arrival_time for r in fin])
    rep = report(method, fin, counters=RunCounters(
        reranks=sched.rerank_count if cfg.rerank_enabled else None))
    return {
        "mean_latency_s": float(e2e.mean()),
        "p99_latency_s": float(np.percentile(e2e, 99)),
        "avg_per_token_latency_s": rep.avg_per_token_latency,
        "p90_per_token_latency_s": rep.p90_per_token_latency,
        "makespan_s": rep.makespan,
        "preemptions": int(sum(r.preempt_count for r in fin)),
        "pinned": int(sum(1 for r in fin if r.boosted)),
        "reranks": sched.rerank_count if cfg.rerank_enabled else None,
        "rerank_preemptions": (int(sum(r.rerank_preemptions or 0
                                       for r in fin))
                               if cfg.rerank_enabled else None),
    }


def run_sweep(n: int, sigmas=NOISE_SIGMAS) -> dict:
    base = skewed_trace(n)
    out = {"n_requests": n, "sigmas": list(sigmas), "by_sigma": {}}
    print(f"skewed-output trace, n={n}, preemption on "
          f"(max_batch={MAX_BATCH}, max_preemptions={MAX_PREEMPTIONS})")
    print(f"{'sigma':>5s} {'method':>9s} {'mean':>9s} {'p99':>9s} "
          f"{'preempt':>7s} {'pinned':>6s}")
    for sigma in sigmas:
        annotate_scores(base, noise_factors(n, sigma))
        row = {m: run_method(base, m) for m in ("fcfs", "static",
                                                "iterative")}
        out["by_sigma"][f"{sigma:g}"] = row
        for m, r in row.items():
            print(f"{sigma:5.1f} {m:>9s} {r['mean_latency_s']:8.2f}s "
                  f"{r['p99_latency_s']:8.2f}s {r['preemptions']:7d} "
                  f"{r['pinned']:6d}")
    clean = out["by_sigma"][f"{sigmas[0]:g}"]
    heavy = out["by_sigma"][f"{sigmas[-1]:g}"]
    out["mean_speedup_vs_static"] = (clean["static"]["mean_latency_s"]
                                     / clean["iterative"]["mean_latency_s"])
    out["p99_speedup_vs_static"] = (clean["static"]["p99_latency_s"]
                                    / clean["iterative"]["p99_latency_s"])
    out["heavy_noise_vs_fcfs"] = (heavy["iterative"]["mean_latency_s"]
                                  / heavy["fcfs"]["mean_latency_s"])

    # ISSUE acceptance bars
    assert out["mean_speedup_vs_static"] >= 1.2, \
        f"iterative mean speedup {out['mean_speedup_vs_static']:.2f}x < 1.2x"
    assert clean["iterative"]["p99_latency_s"] \
        < clean["static"]["p99_latency_s"], \
        f"iterative p99 not strictly better ({out['p99_speedup_vs_static']:.2f}x)"
    assert out["heavy_noise_vs_fcfs"] <= 1.0, \
        (f"iterative degrades worse than FCFS at sigma={sigmas[-1]} "
         f"({out['heavy_noise_vs_fcfs']:.2f}x)")
    print(f"  [iterative] mean {out['mean_speedup_vs_static']:.2f}x / "
          f"p99 {out['p99_speedup_vs_static']:.2f}x better than static; "
          f"{out['heavy_noise_vs_fcfs']:.2f}x FCFS at sigma={sigmas[-1]}")
    return out


BENCH = ServingBench(
    name="iterative_rank",
    run=lambda args: run_sweep(args.requests
                               or (220 if args.smoke else 1500)),
    section=lambda r: {
        "mean_speedup_vs_static": r["mean_speedup_vs_static"],
        "p99_speedup_vs_static": r["p99_speedup_vs_static"],
        "heavy_noise_vs_fcfs": r["heavy_noise_vs_fcfs"],
        "by_sigma": r["by_sigma"],
    },
    headline=lambda r: (
        "iterative_rank",
        r["by_sigma"]["0"]["iterative"]["mean_latency_s"] * 1e6,
        f"mean {r['mean_speedup_vs_static']:.2f}x / p99 "
        f"{r['p99_speedup_vs_static']:.2f}x vs static; "
        f"{r['heavy_noise_vs_fcfs']:.2f}x FCFS at heaviest noise"),
    add_args=lambda ap: ap.add_argument(
        "--requests", type=int, default=None, help="override trace length"),
    smoke_help="tiny CI config: prove the sweep runs and all three "
               "acceptance bars hold",
)


def main(argv=None) -> dict:
    return bench_main(BENCH, argv)


if __name__ == "__main__":
    main()
