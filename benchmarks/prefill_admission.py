"""Micro-bench: burst admission latency on the real path — sequential
per-request prefill (the pre-refactor behaviour) vs batched bucketed prefill
(one jitted ``forward_seq`` per prompt-length bucket per cycle).

    PYTHONPATH=src python -m benchmarks.prefill_admission [--batch 8]

Both modes pre-compile their shape grid (``Engine.warmup``, the vLLM-style
startup warmup), then serve full-batch bursts so every rep is one admission
cycle. Reported per mode: warmup seconds, prefill dispatches, and wall
seconds spent in admission.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_smoke_config
from repro.core.scheduler.policies import fcfs
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.models import transformer as tfm
from repro.serving.engine import Engine


def _burst(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        words = int(rng.integers(2, 28))
        prompt = " ".join(f"w{rng.integers(0, 999)}" for _ in range(words))
        reqs.append(Request(i, prompt, 0.0, words + 1, int(rng.integers(2, 6))))
    return reqs


def run(batch: int = 8, reps: int = 4, arch: str = "llama3_2_3b") -> dict:
    cfg = get_smoke_config(arch).replace(dtype="float32", vocab_size=2048)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    results = {}
    for mode, bucketed in (("sequential", False), ("bucketed", True)):
        sched = Scheduler(policy=fcfs(), max_batch=batch)
        eng = Engine(cfg, params, sched, cache_len=64, prompt_len=32,
                     bucketed=bucketed)
        warm_s = eng.warmup()
        for rep in range(reps):           # full-batch burst = 1 admission cycle
            eng.submit(_burst(batch, seed=rep))
            eng.run()
            assert len(eng.finished) == batch * (rep + 1)
        results[mode] = dict(dispatches=eng.backend.prefill_dispatches,
                             prefill_s=eng.backend.prefill_seconds,
                             warmup_s=warm_s)
        print(f"{mode:10s} warmup={warm_s:6.1f} s "
              f"dispatches={eng.backend.prefill_dispatches:3d} "
              f"(over {reps} bursts of {batch})  "
              f"admission={eng.backend.prefill_seconds * 1e3:8.1f} ms")
    seq, buk = results["sequential"], results["bucketed"]
    speedup = seq["prefill_s"] / max(buk["prefill_s"], 1e-9)
    print(f"bucketed admission: {seq['dispatches']}→{buk['dispatches']} "
          f"dispatches, {speedup:.2f}x faster")
    emit("prefill_admission", buk["prefill_s"] * 1e6 / (batch * reps),
         f"admission speedup {speedup:.2f}x "
         f"({seq['dispatches']}->{buk['dispatches']} dispatches)")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reps", type=int, default=4)
    ap.add_argument("--arch", default="llama3_2_3b")
    args = ap.parse_args()
    run(args.batch, args.reps, args.arch)
