"""Shared benchmark infrastructure: sized settings + in-process caches.

Every table benchmark goes through ``get_predictor`` so a predictor trained
for Table II is reused by Tables III/IV/scheduling/cross-model without
retraining (single-core container budget).

FAST mode (default) uses reduced corpus/epoch sizes; ``--full`` restores the
paper-scale protocol (5 epochs etc.). Sizes are recorded in every output row.
"""
from __future__ import annotations

import functools
import json
import os
import time
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from repro.core.predictor import (PredictorConfig, TrainSettings,
                                  evaluate_tau, train_predictor)
from repro.data.synthetic import MODELS, make_corpus, sample_lengths

FAST = os.environ.get("REPRO_BENCH_FULL", "0") != "1"


@dataclass(frozen=True)
class BenchScale:
    n_train: int
    n_test: int
    epochs: int
    pairs_per_epoch: int
    burst: int
    sweep_requests: int


def scale() -> BenchScale:
    if FAST:
        return BenchScale(n_train=1500, n_test=400, epochs=2,
                          pairs_per_epoch=2560, burst=2000,
                          sweep_requests=600)
    return BenchScale(n_train=8000, n_test=1500, epochs=5,
                      pairs_per_epoch=6400, burst=2000, sweep_requests=2000)


@functools.lru_cache(maxsize=None)
def corpus(dataset: str, split: str):
    sc = scale()
    if split == "train":
        return make_corpus(dataset, sc.n_train, seed=0)
    return make_corpus(dataset, sc.n_test, seed=424242)


@functools.lru_cache(maxsize=None)
def lengths(dataset: str, split: str, model: str):
    run_seed = 0 if split == "train" else 9
    return sample_lengths(corpus(dataset, split), model, run_seed=run_seed)


@functools.lru_cache(maxsize=None)
def get_predictor(dataset: str, model: str, method: str = "pairwise",
                  backbone: str = "bert", delta: float = -1.0):
    """Train (or fetch cached) predictor. delta=-1 → the model's paper δ."""
    sc = scale()
    if delta < 0:
        delta = MODELS[model].delta
    st = TrainSettings(method=method, epochs=sc.epochs,
                       pairs_per_epoch=sc.pairs_per_epoch, delta=delta)
    t0 = time.perf_counter()
    pred = train_predictor(corpus(dataset, "train").prompts,
                           lengths(dataset, "train", model),
                           backbone=backbone, settings=st)
    pred.train_seconds = time.perf_counter() - t0
    return pred


def tau_of(pred, dataset: str, model: str) -> float:
    return evaluate_tau(pred, corpus(dataset, "test").prompts,
                        lengths(dataset, "test", model))


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The repo-wide CSV row convention: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


BENCH_SERVING_JSON = Path(__file__).resolve().parent.parent / \
    "BENCH_serving.json"


def record_serving_bench(section: str, payload: dict,
                         path: Path = BENCH_SERVING_JSON) -> None:
    """Merge one serving benchmark's headline numbers into the repo-root
    consolidated ``BENCH_serving.json`` (created on first write, sections
    keyed by benchmark name so re-runs overwrite their own entry only)."""
    data = {}
    if path.exists():
        data = json.loads(path.read_text())
    data[section] = payload
    path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
