"""Shared benchmark infrastructure: sized settings, in-process caches, and
the serving-benchmark runner.

Every table benchmark goes through ``get_predictor`` so a predictor trained
for Table II is reused by Tables III/IV/scheduling/cross-model without
retraining (single-core container budget).

FAST mode (default) uses reduced corpus/epoch sizes; ``--full`` restores the
paper-scale protocol (5 epochs etc.). Sizes are recorded in every output row.

Serving benchmarks declare a :class:`ServingBench` and delegate their
``main`` to :func:`bench_main`, which owns the boilerplate every script used
to hand-roll: ``--smoke`` / ``--json`` / ``--seed`` arg parsing, the
``name,us_per_call,derived`` CSV row, the ``BENCH_serving.json`` section
merge, and the optional JSON artifact. ``benchmarks/run.py`` enumerates the
same registry, so adding a benchmark is one ``ServingBench`` declaration —
not another copy of the arg parser.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Optional, Tuple

import numpy as np

from repro.core.predictor import (PredictorConfig, TrainSettings,
                                  evaluate_tau, train_predictor)
from repro.data.synthetic import MODELS, make_corpus, sample_lengths

FAST = os.environ.get("REPRO_BENCH_FULL", "0") != "1"


@dataclass(frozen=True)
class BenchScale:
    n_train: int
    n_test: int
    epochs: int
    pairs_per_epoch: int
    burst: int
    sweep_requests: int


def scale() -> BenchScale:
    if FAST:
        return BenchScale(n_train=1500, n_test=400, epochs=2,
                          pairs_per_epoch=2560, burst=2000,
                          sweep_requests=600)
    return BenchScale(n_train=8000, n_test=1500, epochs=5,
                      pairs_per_epoch=6400, burst=2000, sweep_requests=2000)


@functools.lru_cache(maxsize=None)
def corpus(dataset: str, split: str):
    sc = scale()
    if split == "train":
        return make_corpus(dataset, sc.n_train, seed=0)
    return make_corpus(dataset, sc.n_test, seed=424242)


@functools.lru_cache(maxsize=None)
def lengths(dataset: str, split: str, model: str):
    run_seed = 0 if split == "train" else 9
    return sample_lengths(corpus(dataset, split), model, run_seed=run_seed)


@functools.lru_cache(maxsize=None)
def get_predictor(dataset: str, model: str, method: str = "pairwise",
                  backbone: str = "bert", delta: float = -1.0):
    """Train (or fetch cached) predictor. delta=-1 → the model's paper δ."""
    sc = scale()
    if delta < 0:
        delta = MODELS[model].delta
    st = TrainSettings(method=method, epochs=sc.epochs,
                       pairs_per_epoch=sc.pairs_per_epoch, delta=delta)
    t0 = time.perf_counter()
    pred = train_predictor(corpus(dataset, "train").prompts,
                           lengths(dataset, "train", model),
                           backbone=backbone, settings=st)
    pred.train_seconds = time.perf_counter() - t0
    return pred


def tau_of(pred, dataset: str, model: str) -> float:
    return evaluate_tau(pred, corpus(dataset, "test").prompts,
                        lengths(dataset, "test", model))


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The repo-wide CSV row convention: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


BENCH_SERVING_JSON = Path(__file__).resolve().parent.parent / \
    "BENCH_serving.json"


def record_serving_bench(section: str, payload: dict,
                         path: Path = BENCH_SERVING_JSON) -> None:
    """Merge one serving benchmark's headline numbers into the repo-root
    consolidated ``BENCH_serving.json`` (created on first write, sections
    keyed by benchmark name so re-runs overwrite their own entry only)."""
    data = {}
    if path.exists():
        data = json.loads(path.read_text())
    data[section] = payload
    path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")


# --------------------------------------------------------- serving-bench runner
@dataclass(frozen=True)
class ServingBench:
    """One serving benchmark, declaratively.

    ``run(args)`` does the actual work (acceptance assertions included) and
    returns the full results dict; ``section(results)`` reduces it to the
    ``BENCH_serving.json`` payload; ``headline(results)`` yields the
    ``(us_per_call, derived)`` pair(s) for the repo-wide CSV row convention;
    ``add_args`` hooks extra benchmark-specific flags onto the shared
    parser. Everything else — ``--smoke`` / ``--json`` / ``--seed``, the
    section merge, the artifact write — is :func:`bench_main`'s job.
    """
    name: str
    run: Callable[[argparse.Namespace], dict]
    section: Callable[[dict], dict]
    headline: Optional[Callable[[dict], Tuple]] = None
    add_args: Optional[Callable[[argparse.ArgumentParser], None]] = None
    smoke_help: str = "tiny CI config: prove the acceptance bars hold"


def bench_main(bench: ServingBench, argv=None) -> dict:
    """The one arg-parse/emit/record path every serving benchmark shares."""
    ap = argparse.ArgumentParser(prog=f"benchmarks.{bench.name}")
    ap.add_argument("--smoke", action="store_true", help=bench.smoke_help)
    ap.add_argument("--json", default=None,
                    help="write full results to this path")
    ap.add_argument("--seed", type=int, default=0,
                    help="trace seed (determinism knob)")
    if bench.add_args is not None:
        bench.add_args(ap)
    args = ap.parse_args(argv)

    results = bench.run(args)

    if bench.headline is not None:
        rows = bench.headline(results)
        # one (name, us, derived) row or a list of them
        if rows and not isinstance(rows[0], (tuple, list)):
            rows = [rows]
        for row in rows:
            emit(*row)
    record_serving_bench(bench.name, bench.section(results))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return results


#: Registry for ``benchmarks/run.py``: import path → ServingBench attribute.
#: Every module here exposes ``BENCH`` and a ``main(argv)`` delegating to
#: :func:`bench_main`, so the driver can execute them uniformly.
SERVING_BENCHES = ("router", "iterative_rank", "fault_tolerance",
                   "workload_harness")
