"""Paper Table II: Kendall τ_b across datasets, LLMs, and ranking approaches
(listwise / pointwise / PARS pairwise)."""
from __future__ import annotations

import time

from benchmarks.common import emit, get_predictor, tau_of
from repro.core.predictor import METHODS
from repro.data.synthetic import DATASETS, MODELS


def run(datasets=DATASETS, models=tuple(MODELS)) -> dict:
    print("# Table II analogue — Kendall tau_b by ranking method")
    print(f"{'dataset':8s} {'model':6s} | {'listwise':>9s} {'pointwise':>9s} "
          f"{'pairwise':>9s}")
    results = {}
    t0 = time.perf_counter()
    for ds in datasets:
        for m in models:
            row = {}
            for method in ("listwise", "pointwise", "pairwise"):
                pred = get_predictor(ds, m, method=method)
                row[method] = tau_of(pred, ds, m)
            results[(ds, m)] = row
            print(f"{ds:8s} {m:6s} | {row['listwise']:9.3f} "
                  f"{row['pointwise']:9.3f} {row['pairwise']:9.3f}")
    us = (time.perf_counter() - t0) * 1e6
    wins = sum(1 for r in results.values()
               if r["pairwise"] >= max(r["listwise"], r["pointwise"]) - 0.02)
    emit("table2_rank_methods", us,
         f"pairwise best-or-tied in {wins}/{len(results)} combos")
    return results


if __name__ == "__main__":
    run()
