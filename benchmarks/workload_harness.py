"""SLO-grade multi-tenant workload harness: the surface PARS is judged on.

Every other benchmark isolates one mechanism (re-ranking, routing, shedding)
on a hand-rolled trace. This harness replays *declarative* multi-tenant
workloads (``repro.serving.workloads``: bursty on/off arrivals, multi-turn
conversations with shared prefixes, reasoning long-tail outputs, priority
classes carrying TTFT/ITL SLOs) through the same ``ServingCore`` /
``ReplicaRouter`` the rest of the repo uses, and scores runs the way
production schedulers are scored — per-class SLO attainment and goodput
(``metrics.slo_report``), not means.

Scenarios (``--scenario``, default all):

* ``multitenant`` — the headline: {fcfs, pars, pars_rerank} on a contended
  bursty trace where an interactive chat tenant (tight TTFT/ITL SLOs)
  competes with a long-output batch tenant. Scores are a noisy oracle
  (``true_length * exp(sigma * N)``, one shared realization — the stand-in
  for a trained predictor, per the mispredict-sweep precedent in
  ``iterative_rank``). Acceptance: pars_rerank's attainment on the
  contended interactive class is *strictly* better than fcfs's.
* ``overload_shed`` — the same class structure under a burst that trips
  sustained-overload shedding. Acceptance: shedding fires, and the
  priority-1 interactive class is shed at a strictly lower rate than the
  priority-0 batch class (class-aware victim selection).
* ``starvation`` — folds the old ``starvation_sweep`` benchmark: the
  starvation-threshold sweep (10 s / 30 s / 120 s / inf) under PARS on an
  overloaded trace, now with SLO attainment alongside max-wait/boost
  counts. Acceptance: a finite threshold strictly bounds the worst-case
  wait vs. threshold = inf.
* ``rate_sweep`` — folds the old ``scheduling_latency`` benchmark (paper
  §IV-D): {fcfs, pars, oracle} across arrival-rate multipliers; the sigma
  axis replaces per-method trained predictors (sigma = 0 is the oracle
  ranker, sigma = 0.3 a PARS-quality one). Acceptance: at the highest
  rate, pars beats fcfs on mean per-token latency.
* ``routed`` — the multitenant trace over 2 replicas: prefix-affinity
  routing vs round-robin, scored by SLO attainment and cross-replica
  conversation-prefix hit rate. Acceptance: affinity's hit rate is at
  least round-robin's.

Every scenario constructs cores exclusively from :class:`ServingConfig`
(no loose core kwargs anywhere) and emits one consolidated
``workload_harness`` section into the repo-root ``BENCH_serving.json``.

    PYTHONPATH=src python -m benchmarks.workload_harness            # full
    PYTHONPATH=src python -m benchmarks.workload_harness --smoke --json o.json
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ServingBench, bench_main
from repro.core.scheduler.policies import fcfs, predictor_sjf
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.config import ServingConfig
from repro.serving.metrics import (RunCounters, SLOReport, report, slo_report)
from repro.serving.router import ReplicaRouter
from repro.serving.simulator import (CostModel, clone_requests, make_sim_core,
                                     make_sim_replicas)
from repro.serving.workloads import (SLO, ArrivalPhase, ConversationSpec,
                                     OutputDist, PriorityClass, TenantSpec,
                                     WorkloadSpec, generate_trace,
                                     trace_summary)

#: The contended class the headline acceptance bar is measured on.
CONTENDED_CLASS = "interactive"
PARS_SIGMA = 0.3          # noisy-oracle score quality standing in for PARS
MAX_BATCH = 8


def bursty_spec(*, seed: int = 0, duration_s: float = 30.0,
                rate_scale: float = 1.0) -> WorkloadSpec:
    """The harness's reference workload: an interactive chat tenant with
    bursty on/off arrivals, multi-turn conversations and tight SLOs,
    competing with a steady batch tenant whose reasoning long-tail outputs
    are the contention source, plus a smaller agent tenant in between."""
    return WorkloadSpec(tenants=(
        TenantSpec(
            "chat",
            phases=(ArrivalPhase(3.0 * rate_scale, 6.0),
                    ArrivalPhase(0.4 * rate_scale, 6.0)),
            classes=(PriorityClass(CONTENDED_CLASS,
                                   SLO(ttft_s=1.0, itl_s=0.25),
                                   priority=1, weight=3.0),
                     PriorityClass("best_effort", SLO(ttft_s=8.0),
                                   priority=0, weight=1.0)),
            outputs=OutputDist(median_tokens=12, sigma=0.4),
            conversation=ConversationSpec(max_turns=3, p_continue=0.55,
                                          think_time_s=1.0, turn_words=10),
            system_words=48),
        TenantSpec(
            "batch",
            phases=(ArrivalPhase(1.2 * rate_scale, duration_s),),
            classes=(PriorityClass("batch", SLO(), priority=0),),
            outputs=OutputDist(median_tokens=140, sigma=0.7,
                               long_frac=0.10, long_scale=4.0),
            system_words=16),
        TenantSpec(
            "agent",
            phases=(ArrivalPhase(0.8 * rate_scale, 4.0),
                    ArrivalPhase(0.0, 8.0)),
            classes=(PriorityClass("agentic", SLO(ttft_s=2.5),
                                   priority=1, weight=1.0),),
            outputs=OutputDist(median_tokens=60, sigma=0.6,
                               long_frac=0.05, long_scale=4.0),
            conversation=ConversationSpec(max_turns=2, p_continue=0.5,
                                          think_time_s=0.5, turn_words=16),
            system_words=32),
    ), duration_s=duration_s, seed=seed)


def annotate_scores(reqs, sigma: float, *, seed: int = 7) -> None:
    """Noisy-oracle predictor stand-in: ``score = true_length * exp(sigma *
    N(0,1))``, one realization shared by every policy run over the trace
    (fair comparison — same predictions, different use). ``scored`` is set
    so the policy's batched arrival scoring is skipped."""
    rng = np.random.default_rng(seed)
    noise = np.exp(rng.normal(0.0, sigma, len(reqs))) if sigma else \
        np.ones(len(reqs))
    for r, f in zip(reqs, noise):
        r.score = float(r.true_length) * float(f)
        r.scored = True


def _policy(name: str):
    return fcfs() if name == "fcfs" else predictor_sjf("pars", None)


def _core_config(policy_name: str, **extra) -> ServingConfig:
    cfg = ServingConfig(prefix_caching=True, record_token_times=True,
                        **extra)
    if policy_name == "pars_rerank":
        cfg = cfg.replace(rerank_every_steps=4, rerank_pin_after=3)
    return cfg


def _run_one(trace, policy_name: str, *, config: ServingConfig,
             max_batch: int = MAX_BATCH, kv_blocks=None,
             starvation_threshold: float = 120.0,
             cost: CostModel = CostModel()):
    """One policy run over (a fresh clone of) the trace → (core, finished,
    SLOReport, LatencyReport). Preemption is on for every policy (the only
    variable is the rank method), which is where static total-length keys
    and rerank's remaining-length keys diverge."""
    reqs = clone_requests(trace)
    annotate_scores(reqs, 0.0 if policy_name == "oracle" else PARS_SIGMA)
    sched = Scheduler(policy=_policy(policy_name), max_batch=max_batch,
                      preemption=True, max_preemptions=4,
                      starvation_threshold=starvation_threshold)
    core = make_sim_core(sched, cost=cost, kv_blocks=kv_blocks,
                         config=config)
    core.submit(reqs)
    finished = core.run()
    assert len(finished) + len(core.dropped) == len(trace), \
        (policy_name, len(finished), len(core.dropped), len(trace))
    srep = slo_report(policy_name, finished, core.dropped)
    lrep = report(policy_name, finished,
                  counters=RunCounters.from_core(core))
    return core, finished, srep, lrep


def _slo_payload(s: SLOReport) -> dict:
    return {
        "slo_attainment": s.slo_attainment,
        "ttft_attainment": s.ttft_attainment,
        "itl_attainment": s.itl_attainment,
        "goodput_tok_s": s.goodput_tok_s,
        "throughput_tok_s": s.throughput_tok_s,
        "n_dropped": s.n_dropped,
        "per_class": {c.name: {
            "slo_attainment": c.slo_attainment,
            "ttft_attainment": c.ttft_attainment,
            "itl_attainment": c.itl_attainment,
            "goodput_tok_s": c.goodput_tok_s,
            "p99_ttft_s": c.p99_ttft_s,
            "n_requests": c.n_requests,
            "n_dropped": c.n_dropped,
        } for c in s.per_class},
        "per_tenant": {t.name: {
            "p99_ttft_s": t.p99_ttft_s,
            "p99_per_token_latency_s": t.p99_per_token_latency,
            "slo_attainment": t.slo_attainment,
        } for t in s.per_tenant},
    }


# ------------------------------------------------------------- multitenant
def run_multitenant(*, seed: int = 0, duration_s: float = 30.0) -> dict:
    spec = bursty_spec(seed=seed, duration_s=duration_s, rate_scale=1.0)
    trace = generate_trace(spec)
    out = {"trace": trace_summary(trace), "policies": {}}
    print(f"multitenant: {len(trace)} requests over {duration_s:g}s")
    for pol in ("fcfs", "pars", "pars_rerank"):
        _, _, srep, lrep = _run_one(trace, pol, config=_core_config(pol))
        out["policies"][pol] = _slo_payload(srep)
        out["policies"][pol]["avg_per_token_latency_s"] = \
            lrep.avg_per_token_latency
        out["policies"][pol]["prefix_hit_rate"] = lrep.prefix_hit_rate
        print(srep.rows())
    contended = {p: out["policies"][p]["per_class"][CONTENDED_CLASS]
                 for p in out["policies"]}
    out["contended_class"] = CONTENDED_CLASS
    out["contended_attainment"] = {p: c["slo_attainment"]
                                   for p, c in contended.items()}
    out["contended_goodput_gain"] = (
        contended["pars_rerank"]["goodput_tok_s"]
        / max(contended["fcfs"]["goodput_tok_s"], 1e-9))
    # ISSUE acceptance bar: pars+rerank strictly better attainment than
    # fcfs on the contended class
    assert contended["pars_rerank"]["slo_attainment"] \
        > contended["fcfs"]["slo_attainment"], \
        (f"pars_rerank attainment "
         f"{contended['pars_rerank']['slo_attainment']:.3f} not strictly "
         f"above fcfs {contended['fcfs']['slo_attainment']:.3f} on "
         f"{CONTENDED_CLASS}")
    print(f"  [multitenant] {CONTENDED_CLASS} attainment "
          + " ".join(f"{p}={c['slo_attainment']:.2f}"
                     for p, c in contended.items())
          + f"; goodput gain {out['contended_goodput_gain']:.2f}x")
    return out


# ------------------------------------------------------------ overload_shed
def run_overload_shed(*, seed: int = 0, duration_s: float = 12.0) -> dict:
    # 4x the reference rate against a max_batch=4 core: sustained overload
    spec = bursty_spec(seed=seed, duration_s=duration_s, rate_scale=4.0)
    trace = generate_trace(spec)
    cfg = _core_config("pars", shed_queue_depth=24, shed_sustain_steps=3,
                       shed_predicted_tokens=180.0)
    core, finished, srep, lrep = _run_one(trace, "pars", config=cfg,
                                          max_batch=4)
    shed = [r for r in core.dropped if r.drop_reason == "overload"]
    by_prio = {0: [r for r in trace if r.priority == 0],
               1: [r for r in trace if r.priority == 1]}
    shed_rate = {p: (sum(1 for r in shed if r.priority == p)
                     / max(len(by_prio[p]), 1)) for p in (0, 1)}
    out = {
        "trace": trace_summary(trace),
        "slo": _slo_payload(srep),
        "dropped_total": lrep.dropped_total,
        "shed": lrep.shed,
        "shed_rate_priority0": shed_rate[0],
        "shed_rate_priority1": shed_rate[1],
    }
    assert lrep.shed >= 1, "sustained overload never shed"
    # class-aware victim selection: the priority-1 interactive/agentic
    # classes must survive strictly better than priority-0 work
    assert shed_rate[1] < shed_rate[0], \
        f"priority-1 shed rate {shed_rate[1]:.3f} not below " \
        f"priority-0 {shed_rate[0]:.3f}"
    print(f"  [overload_shed] {int(lrep.shed)} shed of {len(trace)}; "
          f"shed rate p0={shed_rate[0]:.2f} vs p1={shed_rate[1]:.2f}")
    return out


# -------------------------------------------------------------- starvation
def run_starvation(*, seed: int = 0, duration_s: float = 20.0) -> dict:
    """The old ``starvation_sweep`` scenario on a harness trace: PARS under
    overload, threshold sweep, plus SLO attainment per threshold. The
    overload is moderate (2x) on purpose: under extreme overload every
    wait is drain-dominated and the threshold can't move the worst case;
    at 2x the worst case IS the SJF-starved long request, which boosting
    admits earlier."""
    spec = bursty_spec(seed=seed, duration_s=duration_s, rate_scale=2.0)
    trace = generate_trace(spec)
    out = {"trace": trace_summary(trace), "by_threshold": {}}
    print(f"{'threshold':>10s} {'avg ms/tok':>11s} {'max wait s':>11s} "
          f"{'boosted':>8s} {'attain':>7s}")
    for thresh in (5.0, 15.0, 60.0, float("inf")):
        _, fin, srep, lrep = _run_one(trace, "pars",
                                      config=_core_config("pars"),
                                      starvation_threshold=thresh)
        waits = np.array([r.start_time - r.arrival_time for r in fin])
        boosted = int(sum(r.boosted for r in fin))
        label = "inf" if np.isinf(thresh) else f"{thresh:g}s"
        out["by_threshold"][label] = {
            "avg_per_token_latency_s": lrep.avg_per_token_latency,
            "p90_per_token_latency_s": lrep.p90_per_token_latency,
            "max_wait_s": float(waits.max()),
            "boosted": boosted,
            "slo_attainment": srep.slo_attainment,
        }
        print(f"{label:>10s} {lrep.avg_per_token_latency * 1e3:11.1f} "
              f"{waits.max():11.1f} {boosted:8d} "
              f"{srep.slo_attainment:7.2f}")
    tight, free = out["by_threshold"]["5s"], out["by_threshold"]["inf"]
    assert tight["max_wait_s"] < free["max_wait_s"], \
        "finite starvation threshold did not bound worst-case wait"
    assert tight["boosted"] > 0, "overloaded sweep never boosted anyone"
    print(f"  [starvation] 5s threshold bounds max wait "
          f"{tight['max_wait_s']:.1f}s vs {free['max_wait_s']:.1f}s "
          f"unbounded")
    return out


# -------------------------------------------------------------- rate_sweep
def run_rate_sweep(*, seed: int = 0, duration_s: float = 15.0,
                   rates=(0.5, 1.0, 2.0)) -> dict:
    """The old ``scheduling_latency`` §IV-D shape: policies across
    arrival-rate multipliers; sigma-noise oracle scorers stand in for the
    trained predictor ladder (sigma = 0 → oracle, 0.3 → PARS-quality)."""
    out = {"rates": list(rates), "by_rate": {}}
    for rate in rates:
        spec = bursty_spec(seed=seed, duration_s=duration_s,
                           rate_scale=rate)
        trace = generate_trace(spec)
        row = {}
        print(f"# rate x{rate:g}: {len(trace)} requests")
        for pol in ("fcfs", "pars", "oracle"):
            _, _, srep, lrep = _run_one(trace, pol,
                                        config=_core_config(pol))
            row[pol] = {
                "avg_per_token_latency_s": lrep.avg_per_token_latency,
                "p90_per_token_latency_s": lrep.p90_per_token_latency,
                "avg_ttft_s": lrep.avg_ttft,
                "slo_attainment": srep.slo_attainment,
                "goodput_tok_s": srep.goodput_tok_s,
            }
            print("  " + lrep.row())
        out["by_rate"][f"{rate:g}"] = row
    top = out["by_rate"][f"{rates[-1]:g}"]
    out["top_rate_speedup"] = (top["fcfs"]["avg_per_token_latency_s"]
                               / top["pars"]["avg_per_token_latency_s"])
    assert top["pars"]["avg_per_token_latency_s"] \
        < top["fcfs"]["avg_per_token_latency_s"], \
        "pars not below fcfs mean per-token latency at the highest rate"
    print(f"  [rate_sweep] PARS {out['top_rate_speedup']:.2f}x vs FCFS "
          f"at rate x{rates[-1]:g}")
    return out


# ------------------------------------------------------------------ routed
def run_routed(*, seed: int = 0, duration_s: float = 20.0,
               n_replicas: int = 2) -> dict:
    spec = bursty_spec(seed=seed, duration_s=duration_s, rate_scale=1.5)
    trace = generate_trace(spec)
    out = {"trace": trace_summary(trace), "by_routing": {}}
    for routing in ("round_robin", "prefix_affinity"):
        reqs = clone_requests(trace)
        annotate_scores(reqs, PARS_SIGMA)
        cores = make_sim_replicas(
            n_replicas, fcfs, max_batch=4, kv_blocks=128,
            config=ServingConfig(prefix_caching=True,
                                 record_token_times=True))
        router = ReplicaRouter(cores, policy=routing, seed=seed)
        router.submit(reqs)
        router.run()
        rrep = router.report()
        srep = slo_report(routing, router.finished, router.all_dropped)
        out["by_routing"][routing] = {
            "slo": _slo_payload(srep),
            "cross_replica_hit_rate": rrep.cross_replica_hit_rate,
            "load_imbalance": rrep.load_imbalance,
            "routed_ttft_p99_s": rrep.routed_ttft_p99_s,
        }
        print("  " + rrep.row())
    rr = out["by_routing"]["round_robin"]["cross_replica_hit_rate"]
    aff = out["by_routing"]["prefix_affinity"]["cross_replica_hit_rate"]
    assert aff >= rr, \
        f"affinity hit rate {aff:.2f} below round_robin {rr:.2f}"
    print(f"  [routed] conversation-prefix hit rate affinity={aff:.2f} "
          f"vs round_robin={rr:.2f}")
    return out


# ------------------------------------------------------------------ driver
SCENARIOS = {
    "multitenant": run_multitenant,
    "overload_shed": run_overload_shed,
    "starvation": run_starvation,
    "rate_sweep": run_rate_sweep,
    "routed": run_routed,
}
#: Smoke-mode duration scale (full durations already run in seconds on CPU;
#: smoke trims the window, not the structure).
SMOKE_SCALE = 0.6


def _run(args) -> dict:
    scenarios = args.scenario or list(SCENARIOS)
    results = {}
    for name in scenarios:
        print(f"== {name}")
        fn = SCENARIOS[name]
        kw = {"seed": args.seed}
        if args.smoke:
            import inspect
            base = inspect.signature(fn).parameters["duration_s"].default
            kw["duration_s"] = base * SMOKE_SCALE
        results[name] = fn(**kw)
    return results


def _headline(results):
    if "multitenant" not in results:
        return []
    m = results["multitenant"]
    att = m["contended_attainment"]
    return ("workload_harness",
            m["policies"]["pars_rerank"]["per_class"][CONTENDED_CLASS]
             ["p99_ttft_s"] * 1e6,
            f"{CONTENDED_CLASS} attainment fcfs={att['fcfs']:.2f} -> "
            f"pars_rerank={att['pars_rerank']:.2f}; goodput "
            f"{m['contended_goodput_gain']:.2f}x")


def _add_args(ap) -> None:
    ap.add_argument("--scenario", action="append",
                    choices=sorted(SCENARIOS), default=None,
                    help="run a subset (repeatable; default: all)")


BENCH = ServingBench(
    name="workload_harness",
    run=_run,
    section=lambda r: r,
    headline=_headline,
    add_args=_add_args,
    smoke_help="trimmed windows, same structure: prove every scenario's "
               "acceptance bar holds",
)


def main(argv=None) -> dict:
    return bench_main(BENCH, argv)


if __name__ == "__main__":
    main()
