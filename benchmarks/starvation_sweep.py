"""Beyond-paper fairness study: starvation threshold vs latency/fairness.

The paper fixes the starvation-prevention threshold at 2 minutes. This sweep
quantifies the trade-off PARS deployments tune: lower thresholds bound the
worst-case wait (fairness) at the cost of average per-token latency drifting
from pure SJF toward FCFS.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import corpus, emit, get_predictor, lengths, scale
from repro.core.scheduler.policies import make_policy
from repro.core.scheduler.scheduler import Scheduler
from repro.data.workload import make_requests, poisson_arrivals
from repro.serving.metrics import report
from repro.serving.simulator import simulate


def run() -> dict:
    sc = scale()
    rng = np.random.default_rng(13)
    pred = get_predictor("alpaca", "llama", method="pairwise")
    c, L = corpus("alpaca", "test"), lengths("alpaca", "test", "llama")
    idx = rng.integers(0, len(c.prompts), sc.burst)

    # overloaded Poisson arrivals (≈1.5× sustainable rate): waits exceed the
    # thresholds while arrival times stay distinct so boosted-FIFO is visible
    arrivals = poisson_arrivals(sc.burst, rate=12.0, seed=3)
    print("# starvation threshold sweep — PARS, overloaded poisson n =", sc.burst)
    print(f"{'threshold':>10s} {'avg ms/tok':>11s} {'p90 ms/tok':>11s} "
          f"{'max wait s':>11s} {'boosted':>8s}")
    results = {}
    t0 = time.perf_counter()
    for thresh in (10.0, 30.0, 120.0, 1e9):
        reqs = make_requests(c, L, arrivals, indices=idx)
        sched = Scheduler(policy=make_policy("pars", pred), max_batch=16,
                          starvation_threshold=thresh)
        fin = simulate(reqs, sched)
        rep = report("pars", fin)
        waits = np.array([r.start_time - r.arrival_time for r in fin])
        boosted = sum(r.boosted for r in fin)
        results[thresh] = (rep, float(waits.max()), boosted)
        label = "inf" if thresh >= 1e9 else f"{thresh:.0f}s"
        print(f"{label:>10s} {rep.avg_per_token_latency * 1e3:11.1f} "
              f"{rep.p90_per_token_latency * 1e3:11.1f} {waits.max():11.1f} "
              f"{boosted:8d}")
    emit("starvation_sweep", (time.perf_counter() - t0) * 1e6,
         "threshold bounds worst-case wait at modest avg-latency cost")
    return results


if __name__ == "__main__":
    run()
