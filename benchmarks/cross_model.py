"""Paper §IV-E: cross-model generalization — predictor trained on the
GPT-4-like generator's lengths, deployed to schedule Llama-like and R1-like
serving (no retraining)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import corpus, emit, get_predictor, lengths, scale, tau_of
from repro.core.scheduler.policies import fcfs, make_policy, oracle_sjf
from repro.data.workload import burst_arrivals, make_requests
from repro.serving.simulator import run_policy


def run() -> dict:
    sc = scale()
    rng = np.random.default_rng(3)
    results = {}
    t0 = time.perf_counter()
    print("# Cross-model PARS: trained on gpt4 lengths, deployed elsewhere")
    for ds in ("alpaca", "lmsys"):
        xm_pred = get_predictor(ds, "gpt4", method="pairwise")
        for target in ("llama", "r1"):
            tau_x = tau_of(xm_pred, ds, target)
            native = get_predictor(ds, target, method="pairwise")
            tau_n = tau_of(native, ds, target)
            c, L = corpus(ds, "test"), lengths(ds, "test", target)
            idx = rng.integers(0, len(c.prompts), sc.burst)
            mk = lambda: make_requests(c, L, burst_arrivals(sc.burst), indices=idx)
            rep_f = run_policy(mk(), fcfs(), max_batch=16)
            rep_x = run_policy(mk(), make_policy("pars", xm_pred), max_batch=16)
            rep_n = run_policy(mk(), make_policy("pars", native), max_batch=16)
            rep_o = run_policy(mk(), oracle_sjf(), max_batch=16)
            results[(ds, target)] = dict(tau_cross=tau_x, tau_native=tau_n,
                                         fcfs=rep_f, cross=rep_x,
                                         native=rep_n, oracle=rep_o)
            print(f"\n{ds}/{target}: tau cross={tau_x:.3f} native={tau_n:.3f}")
            for tag, rep in (("fcfs", rep_f), ("cross-PARS", rep_x),
                             ("PARS", rep_n), ("oracle", rep_o)):
                print(f"  {tag:11s} {rep.row()}")
            print(f"  => cross-model speedup vs FCFS: "
                  f"{rep_f.avg_per_token_latency / rep_x.avg_per_token_latency:.2f}x")
    us = (time.perf_counter() - t0) * 1e6
    sp = min(r["fcfs"].avg_per_token_latency / r["cross"].avg_per_token_latency
             for r in results.values())
    emit("cross_model", us, f"worst-case cross-model speedup vs FCFS {sp:.1f}x")
    return results


if __name__ == "__main__":
    run()
