"""CI gate: the consolidated ``BENCH_serving.json`` must be schema-valid.

Every serving benchmark merges its section into the repo-root
``BENCH_serving.json`` (see :func:`benchmarks.common.record_serving_bench`).
This checker asserts the consolidated file still carries **all** expected
sections with their load-bearing keys — so a refactor that silently stops
recording a benchmark (or a scenario-filtered run that clobbers the full
harness section) fails CI instead of shipping a hollowed-out artifact.

    PYTHONPATH=src python -m benchmarks.check_bench
"""
from __future__ import annotations

import json
import sys

from benchmarks.common import BENCH_SERVING_JSON

#: section name -> keys that must be present (and non-null unless noted)
REQUIRED_SECTIONS = {
    "chunked_prefill": ("p99_itl_speedup", "chunked_p99_itl_s",
                        "unchunked_p99_itl_s"),
    "prefix_caching": ("hit_rate", "warm_ttft_speedup",
                       "prefill_tokens_saved"),
    "paged_decode": ("concurrency_ratio", "real_identical_outputs"),
    "router": ("affinity", "skew"),
    "iterative_rank": ("mean_speedup_vs_static", "p99_speedup_vs_static",
                       "heavy_noise_vs_fcfs"),
    "fault_tolerance": ("crash_failover", "predictor_degradation",
                        "deadline_shed", "no_fault_parity"),
    "workload_harness": ("multitenant", "overload_shed", "starvation",
                         "rate_sweep", "routed"),
}

#: inside workload_harness.multitenant: the SLO headline keys the README
#: and CI summary quote
MULTITENANT_KEYS = ("policies", "contended_class", "contended_attainment",
                    "contended_goodput_gain")


def check(path=BENCH_SERVING_JSON) -> list:
    errors = []
    if not path.exists():
        return [f"{path} missing — run the serving benchmarks first"]
    try:
        data = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        return [f"{path} is not valid JSON: {e}"]
    for section, keys in REQUIRED_SECTIONS.items():
        if section not in data:
            errors.append(f"section missing: {section}")
            continue
        for key in keys:
            if key not in data[section]:
                errors.append(f"{section}.{key} missing")
            elif data[section][key] is None:
                errors.append(f"{section}.{key} is null")
    mt = data.get("workload_harness", {}).get("multitenant", {})
    for key in MULTITENANT_KEYS:
        if mt and key not in mt:
            errors.append(f"workload_harness.multitenant.{key} missing")
    return errors


def main() -> None:
    errors = check()
    if errors:
        print(f"BENCH_serving.json schema check FAILED "
              f"({len(errors)} error(s)):")
        for e in errors:
            print(f"  - {e}")
        sys.exit(1)
    data = json.loads(BENCH_SERVING_JSON.read_text())
    print(f"BENCH_serving.json OK: {len(data)} sections "
          f"({', '.join(sorted(data))})")


if __name__ == "__main__":
    main()
