"""Quickstart: train a PARS predictor and schedule a burst — in ~2 minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.predictor import TrainSettings, evaluate_tau, train_predictor
from repro.core.scheduler.policies import fcfs, make_policy, oracle_sjf
from repro.data.synthetic import make_corpus, sample_lengths
from repro.data.workload import burst_arrivals, make_requests
from repro.serving.simulator import run_policy


def main():
    # 1. data: synthetic "Alpaca-like" prompts + Llama-like response lengths
    train_c = make_corpus("alpaca", 1200, seed=0)
    test_c = make_corpus("alpaca", 400, seed=7)
    train_len = sample_lengths(train_c, "llama")
    test_len = sample_lengths(test_c, "llama", run_seed=3)

    # 2. pairwise predictor with margin ranking loss + delta filtering (§III-A)
    pred = train_predictor(
        train_c.prompts, train_len,
        settings=TrainSettings(method="pairwise", epochs=2,
                               pairs_per_epoch=2560, delta=0.2),
        log_fn=print)
    tau = evaluate_tau(pred, test_c.prompts, test_len)
    print(f"\nKendall tau_b on held-out prompts: {tau:.3f}")

    print("\nsample scores (higher = longer expected response):")
    for p in ["what is topic3", "prove topic42 derive topic42",
              "summarize topic10 please"]:
        print(f"  {pred.score([p])[0]:+7.3f}  {p!r}")

    # 3. predictor-guided SJF vs FCFS vs Oracle on a 400-request burst (§III-B)
    reqs = make_requests(test_c, test_len, burst_arrivals(400))
    print("\nburst of 400 requests, continuous batching (batch=16):")
    for pol in [fcfs(), make_policy("pars", pred), oracle_sjf()]:
        print("  " + run_policy(reqs, pol, max_batch=16).row())


if __name__ == "__main__":
    main()
