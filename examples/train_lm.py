"""Train a language model with the framework's training substrate.

Any assigned architecture family is selectable; the default trains a reduced
config for a few hundred steps on synthetic LM data and checkpoints it
(the ~100M full-config variant is the same command with --full on real HW).

    PYTHONPATH=src python examples/train_lm.py --arch llama3.2-3b --steps 200
"""
import argparse

import jax
import numpy as np

from repro.configs import canon, get_config, get_smoke_config
from repro.models import build, example_batch
from repro.training import Adam, cosine_schedule, save_checkpoint, train


def batches(cfg, batch_size, seq, seed=0):
    i = 0
    while True:
        yield example_batch(cfg, batch_size, seq, jax.random.PRNGKey(seed + i))
        i += 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (real-HW scale)")
    ap.add_argument("--out", default="/tmp/repro_lm_ckpt.npz")
    args = ap.parse_args()

    cfg = (get_config(args.arch) if args.full
           else get_smoke_config(args.arch).replace(dtype="float32"))
    bundle = build(cfg, remat="none" if not args.full else "full")
    params = bundle.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"training {cfg.arch_id}: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps")

    opt = Adam(learning_rate=cosine_schedule(3e-4, warmup=20,
                                             total=args.steps),
               clip_norm=1.0)
    params, history = train(cfg, params, batches(cfg, args.batch, args.seq),
                            opt=opt, steps=args.steps, log_every=20)
    save_checkpoint(args.out, params, metadata={"arch": cfg.arch_id,
                                                "steps": args.steps})
    print(f"checkpoint written to {args.out}")
    assert history[-1]["loss"] < history[0]["loss"]


if __name__ == "__main__":
    main()
