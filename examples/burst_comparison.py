"""Paper §IV-D at full burst scale: 2000 simultaneous requests, five policies,
avg + p90 per-token latency (simulator; see serve_e2e.py for the real engine).

    PYTHONPATH=src python examples/burst_comparison.py [--model r1]
"""
import argparse

import numpy as np

from repro.core.predictor import TrainSettings, train_predictor
from repro.core.scheduler.policies import fcfs, make_policy, oracle_sjf
from repro.data.synthetic import MODELS, make_corpus, sample_lengths
from repro.data.workload import burst_arrivals, make_requests
from repro.serving.simulator import run_policy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="llama", choices=list(MODELS))
    ap.add_argument("--dataset", default="alpaca")
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="bound the simulated KV cache (16-token blocks; "
                    "0 = unbounded) — admission defers under pressure")
    args = ap.parse_args()

    train_c = make_corpus(args.dataset, 1500, seed=0)
    L_train = sample_lengths(train_c, args.model)
    delta = MODELS[args.model].delta
    preds = {}
    for method in ("pairwise", "pointwise", "listwise"):
        preds[method] = train_predictor(
            train_c.prompts, L_train,
            settings=TrainSettings(method=method, epochs=2,
                                   pairs_per_epoch=2560, delta=delta))

    test_c = make_corpus(args.dataset, args.n, seed=5)
    L = sample_lengths(test_c, args.model, run_seed=2)
    reqs = make_requests(test_c, L, burst_arrivals(args.n))

    kv = args.kv_blocks or None
    print(f"\n{args.dataset}/{args.model}: burst n={args.n}, batch=16"
          + (f", kv_blocks={kv}" if kv else ""))
    reports = {}
    for name, pol in [
        ("fcfs", fcfs()),
        ("pointwise", make_policy("pointwise", preds["pointwise"])),
        ("listwise", make_policy("listwise", preds["listwise"])),
        ("pars", make_policy("pars", preds["pairwise"])),
        ("oracle", oracle_sjf()),
    ]:
        reports[name] = run_policy(reqs, pol, max_batch=16, kv_blocks=kv)
        print("  " + reports[name].row())
    f, p = reports["fcfs"], reports["pars"]
    print(f"\nPARS speedup vs FCFS: avg "
          f"{f.avg_per_token_latency / p.avg_per_token_latency:.2f}x, p90 "
          f"{f.p90_per_token_latency / p.p90_per_token_latency:.2f}x")


if __name__ == "__main__":
    main()
