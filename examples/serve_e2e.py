"""End-to-end driver (deliverable (b)): serve a small model with batched
requests through the REAL JAX engine — actual forwards, KV cache, continuous
batching — comparing FCFS / PARS / Oracle wall-clock per-token latency.

    PYTHONPATH=src python examples/serve_e2e.py [--requests 48] [--batch 4]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.predictor import TrainSettings, train_predictor
from repro.core.scheduler.policies import fcfs, make_policy, oracle_sjf
from repro.data.synthetic import make_corpus, sample_lengths
from repro.data.workload import burst_arrivals, make_requests
from repro.core.scheduler.scheduler import Scheduler
from repro.models import transformer as tfm
from repro.serving import Engine, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--arch", default="llama3_2_3b",
                    help="smoke-config family to serve")
    ap.add_argument("--max-len", type=int, default=120,
                    help="clip ground-truth lengths for CPU wall-clock")
    ap.add_argument("--seq-prefill", action="store_true",
                    help="disable bucketed prefill (one dispatch per request)")
    args = ap.parse_args()

    # the served LM (reduced config of the selected family, real weights)
    cfg = get_smoke_config(args.arch).replace(dtype="float32", vocab_size=2048)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    print(f"serving {cfg.arch_id} (reduced: {cfg.num_layers}L d{cfg.d_model}) "
          f"on {jax.devices()[0].platform}")

    # train the PARS predictor on a disjoint prompt set
    train_c = make_corpus("alpaca", 1000, seed=1)
    pred = train_predictor(
        train_c.prompts, np.clip(sample_lengths(train_c, "llama"), 1,
                                 args.max_len),
        settings=TrainSettings(method="pairwise", epochs=2,
                               pairs_per_epoch=2048, delta=0.2))

    test_c = make_corpus("alpaca", args.requests, seed=9)
    lengths = np.clip(sample_lengths(test_c, "llama"), 1, args.max_len)

    print(f"\nburst of {args.requests} requests, engine batch={args.batch}, "
          f"real wall-clock:")
    for pol in [fcfs(), make_policy("pars", pred), oracle_sjf()]:
        reqs = make_requests(test_c, lengths, burst_arrivals(args.requests))
        sched = Scheduler(policy=pol, max_batch=args.batch)
        eng = Engine(cfg, params, sched, cache_len=256,
                     bucketed=not args.seq_prefill)
        eng.submit(reqs)
        finished = eng.run()
        rep = report(pol.name, finished)
        print("  " + rep.row())
        print(f"    admission: {eng.backend.prefill_requests} prefills in "
              f"{eng.backend.prefill_dispatches} dispatches "
              f"({eng.backend.prefill_seconds * 1e3:.0f} ms)")


if __name__ == "__main__":
    main()
