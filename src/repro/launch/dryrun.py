import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh) combo.

The two lines above MUST stay the first statements in this module — JAX locks
the device count at first initialization, and the production meshes need 512
placeholder host devices (deliverable (e)).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
        --out results/dryrun
Each run prints memory_analysis / cost_analysis and (optionally) writes a
JSON artifact consumed by benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax

from repro.configs import (ARCH_IDS, INPUT_SHAPES, canon, config_for_shape,
                           get_config, shape_applicable)
from repro.launch.analysis import analyze, model_flops_estimate
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.models.model import batch_spec, decode_specs
from repro.sharding.annotate import DEFAULT_RULES, logical_axis_rules
from repro.sharding.specs import (batch_specs, decode_cache_specs,
                                  param_specs, replicated)
from repro.training.optimizer import Adam
from repro.training.train_loop import make_train_step


def _params_shape(cfg):
    return jax.eval_shape(partial(tfm.init_params, cfg=cfg),
                          jax.random.PRNGKey(0))


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                attn_impl: str = "chunked", remat: str = "full",
                kv_shard: str = "heads", moe_group: int = None,
                microbatch: int = 1, donate: bool = False,
                decode_params: str = "fsdp"):
    """Lower + compile one (arch, shape, mesh). Returns (compiled, meta)."""
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    if not shape_applicable(cfg, shape):
        return None, {"skipped": True,
                      "reason": "long_500k inapplicable (DESIGN.md §5)"}
    cfg = config_for_shape(cfg, shape)
    if moe_group and cfg.moe is not None:
        cfg = cfg.replace(moe=cfg.moe.__class__(
            **{**cfg.moe.__dict__, "group_size": moe_group}))
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"

    p_shape = _params_shape(cfg)
    p_specs = param_specs(p_shape, mesh,
                          fsdp=not (decode_params == "tp"
                                    and shape.kind == "decode"))

    with mesh, logical_axis_rules(mesh, DEFAULT_RULES):
        if shape.kind == "train":
            opt = Adam(learning_rate=1e-4, clip_norm=1.0)
            o_shape = jax.eval_shape(opt.init, p_shape)
            o_specs = param_specs(o_shape.mu, mesh)
            opt_specs = type(o_shape)(step=replicated(mesh), mu=o_specs,
                                      nu=o_specs)
            b_shape = batch_spec(cfg, shape)
            b_specs = batch_specs(b_shape, mesh)
            step = make_train_step(cfg, opt, attn_impl=attn_impl, remat=remat,
                                   microbatch=microbatch)
            jitted = jax.jit(step,
                             in_shardings=(p_specs, opt_specs, b_specs),
                             out_shardings=(p_specs, opt_specs, None),
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(p_shape, o_shape, b_shape)
        elif shape.kind == "prefill":
            b_shape = batch_spec(cfg, shape)
            b_shape = {k: v for k, v in b_shape.items()
                       if k not in ("targets", "loss_mask")}
            b_specs = batch_specs(b_shape, mesh)
            cache_len = (min(shape.seq_len, cfg.sliding_window)
                         if cfg.sliding_window else shape.seq_len)

            def prefill_step(params, batch):
                tokens = batch["tokens"]
                extras = {k: v for k, v in batch.items() if k != "tokens"}
                logits, cache, _ = tfm.forward_seq(
                    params, cfg, tokens, build_cache=True,
                    cache_len=cache_len, attn_impl=attn_impl, remat="none",
                    **{k: batch.get(k) for k in
                       ("vision_embeds", "mrope_positions", "frames")
                       if k in batch})
                return logits[:, -1], cache

            jitted = jax.jit(prefill_step, in_shardings=(p_specs, b_specs))
            lowered = jitted.lower(p_shape, b_shape)
        else:  # decode
            cache_shape, token_shape = decode_specs(cfg, shape)
            c_specs = decode_cache_specs(cache_shape, mesh, kv_shard=kv_shard)
            t_spec = batch_specs({"t": token_shape}, mesh)["t"]

            def serve_step(params, cache, token):
                return tfm.decode_step(params, cfg, cache, token)

            jitted = jax.jit(serve_step,
                             in_shardings=(p_specs, c_specs, t_spec),
                             out_shardings=(None, c_specs),
                             donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(p_shape, cache_shape, token_shape)

        t0 = time.perf_counter()
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t0

    meta = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_devices": int(n_dev), "compile_s": compile_s,
        "attn_impl": attn_impl, "remat": remat, "kv_shard": kv_shard,
        "microbatch": microbatch, "donate": donate,
        "skipped": False,
    }
    return compiled, meta


def run_one(arch, shape_name, *, multi_pod, out_dir=None, verbose=True,
            **kw):
    compiled, meta = lower_combo(arch, shape_name, multi_pod=multi_pod, **kw)
    if meta.get("skipped"):
        if verbose:
            print(f"SKIP  {arch:22s} {shape_name:12s} — {meta['reason']}")
        return meta
    shape = INPUT_SHAPES[shape_name]
    cfg = config_for_shape(get_config(arch), shape)
    rl = analyze(compiled, arch=arch, shape=shape_name,
                 mesh_name=meta["mesh"], n_devices=meta["n_devices"],
                 model_flops=model_flops_estimate(cfg, shape))
    record = {**meta, **rl.asdict()}
    if verbose:
        print(f"OK    {rl.row()}  mem/dev="
              f"{rl.memory_gb_per_device if rl.memory_gb_per_device is None else round(rl.memory_gb_per_device, 2)}GB "
              f"compile={meta['compile_s']:.1f}s")
        try:
            print("      memory_analysis:", compiled.memory_analysis())
        except Exception as e:            # pragma: no cover
            print("      memory_analysis unavailable:", e)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{canon(arch)}__{shape_name}__{meta['mesh']}"
        if kw.get("kv_shard", "heads") != "heads":
            tag += f"__kv-{kw['kv_shard']}"
        if kw.get("attn_impl", "chunked") != "chunked":
            tag += f"__attn-{kw['attn_impl']}"
        if kw.get("microbatch", 1) != 1:
            tag += f"__mb{kw['microbatch']}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="no", choices=["no", "yes", "both"])
    ap.add_argument("--attn-impl", default="chunked",
                    choices=["chunked", "naive"])
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--kv-shard", default="heads", choices=["heads", "seq"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--donate", action="store_true")
    ap.add_argument("--decode-params", default="fsdp", choices=["fsdp", "tp"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = [canon(args.arch)] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]

    failures = []
    for mp in pods:
        for arch in archs:
            for shape in shapes:
                try:
                    run_one(arch, shape, multi_pod=mp, out_dir=args.out,
                            attn_impl=args.attn_impl, remat=args.remat,
                            kv_shard=args.kv_shard, microbatch=args.microbatch,
                            donate=args.donate,
                            decode_params=args.decode_params)
                except Exception:
                    failures.append((arch, shape, mp))
                    print(f"FAIL  {arch:22s} {shape:12s} multi_pod={mp}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print("dry-run: all combos lowered and compiled")


if __name__ == "__main__":
    main()
