"""Production meshes (DESIGN.md §6).

Defined as FUNCTIONS so importing this module never touches JAX device state
(the dry-run must set XLA_FLAGS before any device initialization).

  single-pod : (data=16, model=16)            — 256 chips (one v5e pod)
  multi-pod  : (pod=2, data=16, model=16)     — 512 chips
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np
import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run entry point must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (see launch/dryrun.py)")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_cpu_mesh(model: int = 1) -> Mesh:
    """Degenerate mesh for CPU smoke tests of the sharded code path."""
    devices = jax.devices()[: max(model, 1)]
    return Mesh(np.asarray(devices).reshape(1, len(devices)),
                ("data", "model"))


# TPU v5e hardware constants for the roofline (per chip).
HW = {
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bandwidth": 819e9,        # B/s
    "ici_link_bandwidth": 50e9,    # B/s per link
}
