"""Compiled-artifact analysis: HLO collective accounting + roofline terms.

This container is CPU-only, so the roofline is *derived from the compiled
SPMD program*, not measured: ``cost_analysis()`` supplies per-device FLOPs
and bytes, and the collective traffic is summed from the partitioned HLO text
(collective ops with their per-device output shapes). See EXPERIMENTS.md
§Roofline for the formulas and caveats.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict, Optional

from repro.launch.mesh import HW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# e.g. "%all-gather.3 = bf16[2,128,64]{2,1,0} all-gather(" — also matches
# tuple results "( bf16[..], bf16[..] ) all-reduce("
_LINE_RE = re.compile(
    r"=\s*(?P<shapes>[^=]*?)\s(?P<op>" + "|".join(COLLECTIVE_OPS) + r")\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device payload bytes by collective kind (output-shape convention;
    all-reduce counted 2× — ring RS+AG)."""
    out = {op: 0 for op in COLLECTIVE_OPS}
    for m in _LINE_RE.finditer(hlo_text):
        op = m.group("op")
        b = _shape_bytes(m.group("shapes"))
        out[op] += 2 * b if op == "all-reduce" else b
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: Dict[str, int]
    # seconds
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0           # 6·N(_active)·D useful FLOPs (global)
    useful_fraction: float = 0.0       # model_flops / (flops_per_device·n)
    memory_gb_per_device: Optional[float] = None

    def finalize(self) -> "Roofline":
        self.t_compute = self.flops_per_device / HW["peak_flops_bf16"]
        self.t_memory = self.bytes_per_device / HW["hbm_bandwidth"]
        self.t_collective = (self.collective_bytes_per_device
                             / HW["ici_link_bandwidth"])
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        total = self.flops_per_device * self.n_devices
        self.useful_fraction = (self.model_flops / total) if total else 0.0
        return self

    def asdict(self) -> dict:
        return asdict(self)

    def row(self) -> str:
        return (f"{self.arch:22s} {self.shape:12s} {self.mesh:9s} "
                f"C={self.t_compute * 1e3:9.3f}ms "
                f"M={self.t_memory * 1e3:9.3f}ms "
                f"N={self.t_collective * 1e3:9.3f}ms "
                f"-> {self.bottleneck:10s} useful={self.useful_fraction:6.1%}")


def analyze(compiled, *, arch: str, shape: str, mesh_name: str,
            n_devices: int, model_flops: float) -> Roofline:
    # trip-count-aware re-analysis of the partitioned HLO (XLA's own
    # cost_analysis counts scan bodies once — see launch/hlo_cost.py)
    from repro.launch.hlo_cost import analyze_hlo
    hc = analyze_hlo(compiled.as_text())
    flops = hc.flops
    byts = hc.traffic_bytes
    colls = {k: int(v) for k, v in hc.collective_bytes.items()}
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            # donated buffers alias inputs — don't double-count them
            mem = (getattr(ma, "argument_size_in_bytes", 0)
                   + getattr(ma, "temp_size_in_bytes", 0)
                   + getattr(ma, "output_size_in_bytes", 0)
                   - getattr(ma, "alias_size_in_bytes", 0)) / 1e9
    except Exception:
        pass
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=float(sum(colls.values())),
        collectives=colls, model_flops=model_flops,
        memory_gb_per_device=mem,
    ).finalize()


def model_flops_estimate(cfg, shape) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE); decode counts D = batch tokens."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token per seq
