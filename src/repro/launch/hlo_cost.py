"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts each computation ONCE —
``while`` bodies (i.e. every ``lax.scan``: our layer stack, chunked attention,
linear-attention chunks) are under-counted by their trip count, which makes
an 80-layer model look 80× cheaper. This module re-derives per-device costs
from ``compiled.as_text()``:

* parses every computation's ops (shapes from each definition line),
* builds the call graph (while/fusion/call/conditional/map/reduce/sort/scatter),
* multiplies through ``backend_config={"known_trip_count"...}`` on while ops,
* FLOPs: 2·prod(result)·prod(contracted dims) per ``dot`` (+ rough elementwise
  count: 1 FLOP per output element of arithmetic ops),
* HBM traffic ≈ Σ bytes written per op (each produced buffer written once and
  read ≈ once downstream ⇒ traffic ≈ 2× produced bytes; parameters counted
  once). An approximation, but a *consistent* one across combos — documented
  in EXPERIMENTS.md §Roofline,
* collective payload bytes by kind, trip-count-weighted.

Validated against hand-computed matmul/scan cases in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_CALLEE_RE = re.compile(
    r"(?:body|calls|to_apply|branch_computations)=\{?%?([\w.\-,%\s]+)\}?")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

ARITH_OPS = ("add", "subtract", "multiply", "divide", "maximum", "minimum",
             "exponential", "tanh", "rsqrt", "sqrt", "log", "power", "negate",
             "compare", "select", "and", "or", "convert", "cosine", "sine")
COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
_SKIP_BYTES = ("parameter", "tuple", "get-tuple-element", "bitcast",
               "constant", "after-all", "partition-id", "replica-id")


def _shapes(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _nelems(shapes) -> int:
    total = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class Op:
    name: str
    kind: str
    result_text: str
    rest: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> result text
    root: "Op" = None


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and ("->" in line):
            cur = Computation(hdr.group(2))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(2), m.group(3)
        # rhs = "<result shape(s)> <opcode>(operands...), attrs"
        km = re.search(r"\)?\s*([\w\-]+)\(", rhs)
        kind = km.group(1) if km else ""
        paren = rhs.find(kind + "(") if kind else -1
        result_text = rhs[:paren] if paren > 0 else rhs
        op = Op(name, kind, result_text, rhs)
        cur.ops.append(op)
        cur.symbols[name] = result_text
        if m.group(1):                      # ROOT marker
            cur.root = op
    return comps


# operands may carry inline type annotations, e.g.
# dot(f32[128,128]{1,0} %Arg_0.1, f32[128,128]{1,0} %Arg_1.2, …)
_OPERAND_TYPE = r"(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?\s+)?"


def _dot_flops(op: Op, symbols: Dict[str, str]) -> float:
    res_elems = _nelems(_shapes(op.result_text))
    lhs_m = re.search(r"dot\((" + _OPERAND_TYPE + r")%?([\w.\-]+)", op.rest)
    cdims_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if not lhs_m or not cdims_m:
        return 2.0 * res_elems                       # degenerate
    lhs_shape_text = lhs_m.group(1) or symbols.get(lhs_m.group(2), "")
    shp = _shapes(lhs_shape_text)
    if not shp:
        return 2.0 * res_elems
    dims = shp[0][1]
    contract = 1
    for c in cdims_m.group(1).split(","):
        if c and int(c) < len(dims):
            contract *= dims[int(c)]
    return 2.0 * res_elems * contract


_PURE_CONVERT_OPS = frozenset(
    {"parameter", "convert", "bitcast", "get-tuple-element", "tuple", ""})


def _is_pure_convert(comp: "Computation") -> bool:
    """True if the fused computation only casts dtypes (no real compute)."""
    kinds = {op.kind for op in comp.ops}
    return "convert" in kinds and kinds <= _PURE_CONVERT_OPS


def _dus_bytes(op: "Op", comp: "Computation") -> int:
    om = re.search(r"dynamic-update-slice\(" + _OPERAND_TYPE +
                   r"%?[\w.\-]+,\s*(" + _OPERAND_TYPE + r")%?([\w.\-]+)",
                   op.rest)
    if not om:
        return 0
    if om.group(1):                    # update operand's type is inline
        return _nbytes(_shapes(om.group(1)))
    return _nbytes(_shapes(comp.symbols.get(om.group(2), "")))


@dataclass
class CostSummary:
    flops: float = 0.0
    bytes_written: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)

    @property
    def traffic_bytes(self) -> float:
        return 2.0 * self.bytes_written

    def total_collective(self) -> float:
        return float(sum(self.collective_bytes.values()))


def analyze_hlo(hlo: str) -> CostSummary:
    comps = parse_computations(hlo)
    entry = next((n for n in comps
                  if re.search(r"^ENTRY", hlo.split(n)[0].splitlines()[-1]
                               if n in hlo else "")), None)
    # Robust entry detection: the computation declared on the ENTRY line.
    em = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    entry = em.group(1) if em else next(iter(comps))

    # local (single-visit) costs per computation
    local: Dict[str, CostSummary] = {}
    children: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for cname, comp in comps.items():
        cs = CostSummary(collective_bytes={k: 0.0 for k in COLLECTIVE_OPS})
        for op in comp.ops:
            shapes = _shapes(op.result_text)
            if op.kind == "dot":
                cs.flops += _dot_flops(op, comp.symbols)
            elif op.kind in ARITH_OPS:
                cs.flops += _nelems(shapes)
            if op.kind == "dynamic-update-slice":
                # writes only the update operand's bytes, not the full buffer
                cs.bytes_written += _dus_bytes(op, comp) or _nbytes(shapes)
            elif op.kind == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", op.rest)
                callee = comps.get(fm.group(1)) if fm else None
                dus_ops = ([o for o in callee.ops
                            if o.kind == "dynamic-update-slice"]
                           if callee is not None else [])
                if dus_ops:
                    # in-place buffer update (scan ys-stacking, cache writes,
                    # possibly wrapped in XLA:CPU's bf16<->f32 carry converts):
                    # the HBM write is the slice, not the full carried tensor
                    cs.bytes_written += (sum(_dus_bytes(o, callee)
                                             for o in dus_ops)
                                         or _nbytes(shapes))
                elif callee is not None and _is_pure_convert(callee):
                    # XLA:CPU has no native bf16 matmul and materializes f32
                    # copies of bf16 dot operands; on the TPU target the MXU
                    # consumes bf16 directly and these fusions do not exist —
                    # excluded so the memory term reflects the TPU roofline
                    # (EXPERIMENTS.md §Roofline caveats)
                    pass
                else:
                    cs.bytes_written += _nbytes(shapes)
            elif op.kind not in _SKIP_BYTES:
                cs.bytes_written += _nbytes(shapes)
            if op.kind in COLLECTIVE_OPS:
                b = _nbytes(shapes) * (2.0 if op.kind == "all-reduce" else 1.0)
                cs.collective_bytes[op.kind] += b
            # call graph edges
            trip = 1.0
            tm = _TRIP_RE.search(op.rest)
            if tm:
                trip = float(tm.group(1))
            cm = _CALLEE_RE.search(op.rest)
            if cm:
                for callee in re.split(r"[,\s]+", cm.group(1)):
                    callee = callee.strip().lstrip("%")
                    if callee and callee in comps:
                        # condition comps run trip+1 times; negligible — use trip
                        children[cname].append(
                            (callee, trip if op.kind == "while" else 1.0,
                             op.kind == "fusion"))
        local[cname] = cs

    # propagate multipliers from entry (memoized DFS; HLO call graphs are DAGs)
    memo: Dict[str, CostSummary] = {}

    def total(cname: str, depth=0) -> CostSummary:
        if cname in memo:
            return memo[cname]
        if depth > 64:
            return local.get(cname, CostSummary())
        cs = local.get(cname, CostSummary())
        agg = CostSummary(flops=cs.flops, bytes_written=cs.bytes_written,
                          collective_bytes=dict(cs.collective_bytes))
        for callee, mult, via_fusion in children.get(cname, ()):
            sub = total(callee, depth + 1)
            agg.flops += mult * sub.flops
            # ops inside a fusion share the fusion's single output write —
            # their intermediate "bytes written" never touch HBM
            if not via_fusion:
                agg.bytes_written += mult * sub.bytes_written
            for k, v in sub.collective_bytes.items():
                agg.collective_bytes[k] = agg.collective_bytes.get(k, 0.0) + mult * v
        memo[cname] = agg
        return agg

    return total(entry)
