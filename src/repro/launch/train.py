"""Distributed training launcher.

On real hardware this launches the sharded train step on the production mesh;
on this CPU container it runs the same code path on a degenerate (1,1) mesh at
smoke scale (use ``--full`` + the dry-run for the production shapes).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import canon, get_config, get_smoke_config
from repro.launch.mesh import make_cpu_mesh, make_production_mesh
from repro.models import example_batch
from repro.models import transformer as tfm
from repro.sharding.annotate import DEFAULT_RULES, logical_axis_rules
from repro.sharding.specs import batch_specs, param_specs
from repro.training import Adam, cosine_schedule, save_checkpoint
from repro.training.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="published config + production mesh (real HW)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.full:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        cfg = get_smoke_config(args.arch).replace(dtype="float32")
        mesh = make_cpu_mesh()
    print(f"training {cfg.arch_id} on mesh {dict(mesh.shape)}")

    opt = Adam(learning_rate=cosine_schedule(3e-4, 5, args.steps), clip_norm=1.0)
    step_fn = make_train_step(cfg, opt, remat="none" if not args.full else "full",
                              microbatch=args.microbatch)

    with mesh, logical_axis_rules(mesh, DEFAULT_RULES):
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        p_specs = param_specs(jax.eval_shape(lambda: params), mesh)
        params = jax.device_put(params, p_specs)
        opt_state = opt.init(params)
        batch = example_batch(cfg, args.batch, args.seq, jax.random.PRNGKey(1))
        b_specs = batch_specs(batch, mesh)
        jitted = jax.jit(step_fn, in_shardings=(p_specs, None, b_specs),
                         out_shardings=(p_specs, None, None))
        t0 = time.perf_counter()
        for i in range(args.steps):
            batch = example_batch(cfg, args.batch, args.seq,
                                  jax.random.PRNGKey(1 + i))
            params, opt_state, metrics = jitted(params, opt_state, batch)
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                      f"({time.perf_counter() - t0:.1f}s)")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, metadata={"arch": cfg.arch_id})
        print("checkpoint:", args.ckpt)


if __name__ == "__main__":
    main()
