"""Serving launcher: PARS-scheduled continuous batching on the real engine.

    PYTHONPATH=src python -m repro.launch.serve --policy pars --requests 32

Trains the ranking predictor (unless --policy fcfs/oracle), builds the engine
around a reduced model of the chosen family, serves a burst, and prints the
paper's latency metrics. On real hardware the same engine wraps the full
config on the production mesh.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.predictor import TrainSettings, train_predictor
from repro.core.scheduler.policies import make_policy
from repro.data.synthetic import MODELS, make_corpus, sample_lengths
from repro.data.workload import burst_arrivals, make_requests, poisson_arrivals
from repro.models import transformer as tfm
from repro.serving import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b")
    ap.add_argument("--policy", default="pars",
                    choices=["fcfs", "pars", "pointwise", "listwise", "oracle"])
    ap.add_argument("--workload", default="llama", choices=list(MODELS))
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="poisson req/s (0 = burst)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=120)
    ap.add_argument("--starvation", type=float, default=120.0)
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="KV cache budget in 16-token blocks "
                    "(0 = max_batch lanes of cache_len)")
    ap.add_argument("--seq-prefill", action="store_true",
                    help="disable bucketed prefill (one dispatch per request)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(dtype="float32", vocab_size=2048)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)

    predictor = None
    if args.policy in ("pars", "pointwise", "listwise"):
        method = {"pars": "pairwise"}.get(args.policy, args.policy)
        c_train = make_corpus("alpaca", 1000, seed=1)
        predictor = train_predictor(
            c_train.prompts,
            np.clip(sample_lengths(c_train, args.workload), 1, args.max_len),
            settings=TrainSettings(method=method, epochs=2,
                                   pairs_per_epoch=2048,
                                   delta=MODELS[args.workload].delta))
    policy = make_policy(args.policy, predictor)

    c = make_corpus("alpaca", args.requests, seed=9)
    lengths = np.clip(sample_lengths(c, args.workload), 1, args.max_len)
    arrivals = (burst_arrivals(args.requests) if args.rate <= 0
                else poisson_arrivals(args.requests, args.rate, seed=2))
    reqs = make_requests(c, lengths, arrivals)

    rep = serve(cfg, params, reqs, policy, max_batch=args.batch,
                cache_len=256, starvation_threshold=args.starvation,
                kv_blocks=args.kv_blocks or None,
                bucketed=not args.seq_prefill)
    print(rep.row())


if __name__ == "__main__":
    main()
