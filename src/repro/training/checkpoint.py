"""Checkpointing: pytree save/load as .npz with flattened key paths.

No orbax dependency (offline container); format is a plain npz archive whose
keys are '/'-joined tree paths plus a small JSON manifest for dtypes — enough
for real restart semantics (resume training, load a served model).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree: PyTree, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    manifest = {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in flat.items()}
    with open(path + ".json", "w") as f:
        json.dump({"manifest": manifest, "metadata": metadata or {}}, f, indent=1)


def load_checkpoint(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_keys, leaf in leaves_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_keys)
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
