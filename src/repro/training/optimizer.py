"""Optimizers in pure JAX (no optax dependency): Adam / AdamW + utilities.

API mirrors the optax triple: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``; ``apply_updates`` adds.
States are pytrees of f32 so they shard like the params they track.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


@dataclass(frozen=True)
class Adam:
    learning_rate: Any = 1e-3          # float or callable(step) -> lr
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0          # AdamW when > 0
    clip_norm: Optional[float] = None  # global-norm clipping

    def init(self, params: PyTree) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=jax.tree.map(zeros, params),
                         nu=jax.tree.map(zeros, params))

    def _lr(self, step):
        return (self.learning_rate(step) if callable(self.learning_rate)
                else self.learning_rate)

    def update(self, grads: PyTree, state: AdamState,
               params: Optional[PyTree] = None) -> Tuple[PyTree, AdamState]:
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2)
                          * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        sf = step.astype(jnp.float32)
        mhat_c = 1.0 / (1 - b1 ** sf)
        nhat_c = 1.0 / (1 - b2 ** sf)
        lr = self._lr(step)

        def upd(m, n, p):
            u = -lr * (m * mhat_c) / (jnp.sqrt(n * nhat_c) + self.eps)
            if self.weight_decay and p is not None:
                u = u - lr * self.weight_decay * p.astype(jnp.float32)
            return u

        if params is None:
            updates = jax.tree.map(lambda m, n: upd(m, n, None), mu, nu)
        else:
            updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable:
    def lr(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = base_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5
                         * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup, warm, cos)
    return lr
