"""LM training loop: jitted train_step (loss + grad + Adam) and a driver.

``make_train_step`` is also the function the multi-pod dry-run lowers for the
``train_4k`` input shape, so it is kept pure and shardable: (params, opt_state,
batch) -> (params, opt_state, metrics).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import lm_loss
from repro.training.optimizer import Adam, AdamState, apply_updates, global_norm

PyTree = Any


def _split_micro(batch: Dict[str, jax.Array], n: int) -> Dict[str, jax.Array]:
    """Reshape every leaf's batch dim into (n, B/n, ...) for a microbatch scan
    (mrope_positions carries batch at axis 1)."""
    out = {}
    for k, v in batch.items():
        if k == "mrope_positions":          # (3, B, S) -> (n, 3, B/n, S)
            b = v.shape[1]
            out[k] = v.reshape(v.shape[0], n, b // n, *v.shape[2:]).swapaxes(0, 1)
        else:
            b = v.shape[0]
            out[k] = v.reshape(n, b // n, *v.shape[1:])
    return out


def make_train_step(cfg: ModelConfig, opt: Adam, *, attn_impl: str = "chunked",
                    remat: str = "full", microbatch: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatch > 1`` runs gradient accumulation over a ``lax.scan`` of
    batch slices — §Perf iteration 6: peak activation memory scales with
    B/microbatch while HBM traffic and collective volume stay ~constant
    (the lever that fits the large-vocab MoE trains into 16 GB/chip).
    """
    def train_step(params: PyTree, opt_state: AdamState,
                   batch: Dict[str, jax.Array]):
        def loss_fn(p, mb):
            return lm_loss(p, cfg, mb, attn_impl=attn_impl, remat=remat)

        if microbatch == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            micro = _split_micro(batch, microbatch)

            def body(acc, mb):
                g_acc, l_acc = acc
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(body, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = loss / microbatch
            metrics = {}
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics, loss=loss, grad_norm=global_norm(grads))
        return params, opt_state, metrics
    return train_step


def train(cfg: ModelConfig, params: PyTree, batches: Iterable[Dict], *,
          opt: Optional[Adam] = None, steps: int = 100,
          log_every: int = 10, attn_impl: str = "chunked",
          remat: str = "full", log_fn=print) -> Tuple[PyTree, list]:
    """CPU-scale driver (examples / smoke). Returns (params, history)."""
    opt = opt or Adam(learning_rate=3e-4, clip_norm=1.0)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, attn_impl=attn_impl,
                                      remat=remat))
    history = []
    t0 = time.perf_counter()
    for i, batch in enumerate(batches):
        if i >= steps:
            break
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"], m["wall_s"] = i, time.perf_counter() - t0
            history.append(m)
            log_fn(f"step {i:5d} loss {m['loss']:.4f} "
                   f"gnorm {m['grad_norm']:.3f} ({m['wall_s']:.1f}s)")
    return params, history
