"""Training substrate: optimizers, train loop, checkpointing."""
from repro.training.optimizer import Adam, AdamState, apply_updates, cosine_schedule, global_norm
from repro.training.train_loop import make_train_step, train
from repro.training.checkpoint import load_checkpoint, save_checkpoint
