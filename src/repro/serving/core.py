"""Unified serving step loop shared by the real JAX engine and the simulator.

Both execution modes used to hand-roll their own loop, and the two drifted:
the engine enforced the KV budget by reaching into the scheduler's queues,
while the simulator ignored the ``BlockAllocator`` entirely. ``ServingCore``
owns the one canonical cycle —

    arrival delivery → KV-aware admission → chunked prefill → decode
                     → retirement

— parameterized by an :class:`ExecutionBackend` (the jitted JAX engine or the
calibrated cost model) and a :class:`Clock` (wall time or discrete-event
time). KV back-pressure lives in the scheduling path itself: the core installs
an ``admit_hook`` on the scheduler that reserves cache blocks at admission
time, so a request that doesn't fit simply stays in W — no queue surgery, in
either mode. Preemption evictions release their reservation through the
scheduler's ``evict_hook`` the same way.

**Mixed prefill/decode steps (Sarathi-style chunked prefill).** PARS removes
head-of-line blocking at the *queue* level, but an unchunked loop still has
HOL blocking at the *step* level: a burst of long prompts monopolizes the
prefill phase and stalls every running decode until the whole burst is
resident. With ``prefill_chunk_tokens`` set, each :meth:`ServingCore.step`
spends at most that many prompt tokens on prefill — tracked per request via
``Request.prefilled_tokens`` — and then runs one decode iteration for every
request whose prompt is fully resident. Long prompts therefore stream into
the cache across many steps while decodes keep producing tokens in between;
TTFT of the long prompt pays for inter-token latency of everyone else.
``prefill_chunk_tokens=None`` (default) preserves the historical
prefill-to-completion behaviour exactly.

**Prefix caching** (``prefix_caching=True``). Real multi-user traffic shares
prompt prefixes — system prompts, few-shot templates — and re-prefilling
them per request wastes exactly the compute the scheduler protects TTFT
from. At admission the core hashes the request's prompt into block-sized
chunk chains (:func:`~repro.serving.kv_cache.prefix_chunk_hashes`), asks the
allocator for the longest *committed* cached chain, and shares those blocks
instead of claiming fresh ones; the request then starts life with
``prefilled_tokens`` already at the cached offset, so chunk planning only
streams the non-shared suffix and the backend never recomputes the prefix
(the real engine copies the cached KV fragments into the request's lane,
the simulator simply charges fewer prefill tokens). A prompt's own blocks
become hitable (``allocator.commit``) the moment its prefill completes.
The hit is capped at ``prefill_target - 1`` tokens: the final prompt
position is always recomputed so the backend has logits to emit the first
output token from (vLLM does the same on a full-prompt hit).

**Fault tolerance.** Production serving must keep its invariants when
components break, so failure handling is part of the loop, not a wrapper:

* every :meth:`step` first fires an optional ``fault_hook`` (the injection
  point :mod:`repro.serving.faults` attaches to — ``None`` on healthy runs,
  so the hot path carries no testing branches) and bumps ``step_count``;
* a crashed core (:meth:`crash` / ``inject_crash``) raises
  :class:`~repro.serving.faults.ReplicaCrashed` from every probe, submit,
  and tick — the router's failure detector — and :meth:`crash` extracts the
  lost requests (their KV is gone: reservations freed, prefix cache
  cleared cold) for failover; :meth:`restart` rejoins it cold;
* per-request **deadlines** (``Request.deadline``) are enforced every step:
  past-deadline work is cancelled — in-flight requests free their blocks —
  and with ``deadline_time_per_token`` set, a waiting request whose
  predicted service time already overruns its deadline is cancelled at
  admission time instead of wasting prefill (terminal ``CANCELLED``);
* **load shedding**: when queue depth or KV pressure stays over its
  threshold for ``shed_sustain_steps`` consecutive steps, the core sheds
  the worst-ranked non-boosted waiting requests (terminal ``SHED``) and —
  via a gate composed through ``Scheduler.add_admit_gate`` — refuses
  admission to work predicted longer than ``shed_predicted_tokens``, so
  p99 TTFT of admitted traffic degrades gracefully instead of collapsing;
* a request whose admission demand can never fit the cache budget is
  terminally **rejected** at gate time (``REJECTED``) rather than deferred
  forever.

Dropped requests (cancelled / shed / rejected) land in ``ServingCore
.dropped``, never in ``finished`` — request conservation is
``finished + dropped + queued == submitted`` at all times.

New serving behavior lands here once and both modes inherit it — the
multi-replica front-end (:class:`~repro.serving.router.ReplicaRouter`)
drives N of these cores through :meth:`ServingCore.tick` and the router
probes (``queue_depth`` / ``kv_pressure`` / ``predicted_remaining_tokens``
/ ``prefix_affinity_blocks`` / ``next_event_time``) without touching the
loop itself.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from typing import (Callable, Deque, Dict, List, Optional, Protocol, Sequence,
                    Tuple)

from repro.core.scheduler.request import Request, RequestState
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.config import ServingConfig
from repro.serving.faults import ReplicaCrashed
from repro.serving.kv_cache import (UNBOUNDED_BLOCKS, BlockAllocator,
                                    prefix_chunk_hashes)

# One planned unit of prefill work: (request, start, end) in the backend's
# prompt-token space — prefill prompt tokens [start, end) of this request.
PrefillChunk = Tuple[Request, int, int]


class Clock(Protocol):
    def now(self) -> float: ...
    def wait_until(self, t: float) -> None: ...


class WallClock:
    """Real time, origin at construction."""

    def __init__(self) -> None:
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def wait_until(self, t: float) -> None:
        # short sleep, then re-check: arrivals are delivered by the run loop
        if t > self.now():
            time.sleep(min(1e-4, max(t - self.now(), 0.0)))


class VirtualClock:
    """Discrete-event time: advances only when the loop says so."""

    def __init__(self, t: float = 0.0) -> None:
        self.t = t

    def now(self) -> float:
        return self.t

    def wait_until(self, t: float) -> None:
        self.t = max(self.t, t)


class ExecutionBackend(Protocol):
    """What a backend must provide; see ``RealBackend`` / ``SimBackend``."""

    def attach(self, core: "ServingCore") -> None: ...

    def kv_demand(self, req: Request) -> int:
        """Tokens of KV cache this request will occupy while resident."""
        ...

    def prefill_total(self, req: Request) -> int:
        """Prompt tokens this backend must prefill before ``req`` can decode.

        The core plans chunks against this total and a request joins the
        decode batch once ``req.prefilled_tokens`` reaches it. Backends may
        exceed ``req.prompt_len`` (the real engine pads prompts to its token
        bucket; the simulator charges recompute tokens after preemption).
        """
        ...

    def prefill(self, chunks: Sequence[PrefillChunk], now: float) -> float:
        """Process planned prefill chunks; returns the updated time.

        Each ``(req, start, end)`` asks for prompt tokens [start, end) to be
        made KV-resident. ``start == 0`` is a request's first chunk (the
        backend claims residency, e.g. a cache slot); ``end ==
        prefill_total(req)`` completes its prompt (the backend emits the
        first output token). The core updates ``req.prefilled_tokens`` after
        this call returns.
        """
        ...

    def decode(self, now: float) -> float:
        """Advance every *fully prefilled* running request one token;
        returns the updated time."""
        ...

    def release(self, req: Request) -> None:
        """Free backend residency (slot, …) for a retired/evicted request."""
        ...

    def prefix_tokens(self, req: Request) -> Sequence[int]:
        """The token-id stream eligible for prefix sharing, in this
        backend's prompt-token space (the real engine's encoded prompt; the
        simulator's synthetic word-hash stream). Requests whose streams
        share a leading run of whole KV blocks share those blocks. Return
        ``()`` to opt a request out of caching."""
        ...


class ServingCore:
    """The single KV-aware step loop behind the engine and the simulator.

    Behavioural knobs are consolidated in one frozen
    :class:`~repro.serving.config.ServingConfig` — the primary constructor
    is ``ServingCore(scheduler, backend, config=ServingConfig(...))``, with
    the scheduler/backend/allocator/clock *objects* passed alongside as
    wiring. The historical loose-kwargs form still works through a
    deprecation shim (it builds the same config via
    ``ServingConfig.from_kwargs``, so both paths are bit-identical), but
    new code and every in-repo helper construct configs. The knobs, briefly
    (full field docs on :class:`ServingConfig`):

    ``prefill_chunk_tokens`` — per-step prompt-token budget for mixed
    prefill/decode steps (``None`` = prefill each admitted request to
    completion in its admission step, the pre-chunking behaviour).

    ``record_token_times`` — have backends append a wall/virtual timestamp to
    ``Request.token_times`` per generated token, enabling gap-based
    inter-token-latency percentiles in :mod:`repro.serving.metrics`.

    ``prefix_caching`` — share KV blocks between requests whose prompts have
    a common prefix (see module docstring). Off by default: caching changes
    which blocks admissions reserve, so the historical behaviour is opted
    into, never silently altered.

    ``rerank_interval`` / ``rerank_every_steps`` — iterative re-ranking
    (ELIS-style): refresh every queued request's priority key to its
    predicted *remaining* length (``max(score − tokens_done,
    rerank_floor)``) every that-many clock seconds and/or serving cycles.
    The refresh re-scores the waiting queue in one batched predictor call
    (``Policy.refresh``) and the very next scheduling cycle sorts, admits,
    and preempts by the refreshed keys — a long request that has emitted
    most of its predicted tokens stops losing to fresh short prompts.
    Because refreshed ranks can demote the same request repeatedly, the
    core installs a starvation bound on the scheduler
    (``pin_after_demotions = rerank_pin_after``, default 3): a request
    preempted or deferred more often is pinned boosted. Both knobs default
    to off — ranks stay write-once, bit-identical to the historical loop.

    ``kv_reservation`` — ``"full"`` (default, historical) reserves a
    request's worst-case ``backend.kv_demand`` at admission; a resident
    request can never stall on memory, but admission is gated on KV the
    request may not need for thousands of steps. ``"incremental"``
    (vLLM-style paged admission) reserves only the prompt plus one decode
    block up front and grows the reservation block-by-block as decode
    advances (:meth:`_grow_for_decode`) — admitted concurrency at a fixed
    KV budget rises accordingly. When a grow is denied, the lowest-ranked
    other running request is preempted (deterministic: scheduler policy
    key, then req_id; recompute semantics, counted in
    ``Request.grow_preemptions``) so half-decoded requests cannot deadlock
    waiting on each other.
    """

    def __init__(self, scheduler: Scheduler, backend: ExecutionBackend, *,
                 config: Optional[ServingConfig] = None,
                 allocator: Optional[BlockAllocator] = None,
                 clock: Optional[Clock] = None,
                 **legacy_kwargs) -> None:
        if legacy_kwargs:
            # Deprecation shim: the historical loose-kwargs constructor.
            # Translated through ServingConfig.from_kwargs so validation and
            # defaults are exactly the config path's (bit-identical runs are
            # pinned by tests/test_workloads.py).
            if config is not None:
                raise TypeError(
                    "pass either config=ServingConfig(...) or legacy "
                    f"keyword arguments, not both (got both config= and "
                    f"{sorted(legacy_kwargs)})")
            warnings.warn(
                "ServingCore(scheduler, backend, prefill_chunk_tokens=..., "
                "...) is deprecated; pass "
                "config=ServingConfig(...) instead",
                DeprecationWarning, stacklevel=2)
            config = ServingConfig.from_kwargs(**legacy_kwargs)
        self.config = config = config or ServingConfig()
        self.scheduler = scheduler
        self.backend = backend
        self.allocator = allocator or BlockAllocator.unbounded()
        self.clock: Clock = clock or WallClock()
        self.prefill_chunk_tokens = config.prefill_chunk_tokens
        self.record_token_times = config.record_token_times
        self.prefix_caching = config.prefix_caching
        self.kv_reservation = config.kv_reservation
        # Iterative re-ranking cadence: refresh priority keys to predicted
        # *remaining* length every ``rerank_interval`` clock seconds and/or
        # every ``rerank_every_steps`` serving cycles (either one firing
        # triggers a refresh). Off by default — ranks stay write-once.
        self.rerank_interval = config.rerank_interval
        self.rerank_every_steps = config.rerank_every_steps
        self.rerank_floor = config.rerank_floor
        self._rerank_enabled = config.rerank_enabled
        self._steps_since_rerank = 0
        self._last_rerank_t: Optional[float] = None
        if self._rerank_enabled and scheduler.pin_after_demotions is None:
            # starvation bound: re-ranking can demote the same request over
            # and over; pin it boosted after ``rerank_pin_after`` demotions
            scheduler.pin_after_demotions = config.rerank_pin_after
        # req_id -> full chunk-hash chain, computed once per residency: the
        # KV gate re-evaluates every waiting request each cycle under
        # back-pressure, and re-tokenizing + re-hashing a long shared prompt
        # there would make admission O(prompt_len) per cycle
        self._hash_memo: Dict[int, List[int]] = {}
        self.finished: List[Request] = []
        self._pending: Deque[Request] = deque()
        # --------------------------------------------------- fault tolerance
        # Terminally dropped requests (CANCELLED / SHED / REJECTED) — part of
        # request conservation, never of ``finished``.
        self.dropped: List[Request] = []
        # Per-step fault injection point (repro.serving.faults attaches
        # here); ``None`` on healthy runs — the hot path stays branch-free.
        self.fault_hook: Optional[Callable[["ServingCore", float], None]] = None
        self.step_count = 0
        self._crashed = False
        # Deadlines: ``deadline_time_per_token`` (predicted seconds per
        # output token) turns a waiting request's length estimate into a
        # service-time estimate, enabling admission-time shedding of
        # unmeetable deadlines. Deadline enforcement itself activates the
        # first time a submitted request carries one.
        self.deadline_time_per_token = config.deadline_time_per_token
        self._deadlines_seen = False
        self.deadline_cancels = 0
        # Load shedding: sustained-overload detection plus the composed
        # admission gate (below).
        self.shed_queue_depth = config.shed_queue_depth
        self.shed_kv_pressure = config.shed_kv_pressure
        self.shed_sustain_steps = config.shed_sustain_steps
        self.shed_predicted_tokens = config.shed_predicted_tokens
        self._shed_enabled = config.shed_enabled
        self._overload_steps = 0
        self._shed_active = False
        self.shed_count = 0
        # Gate-time terminal rejection (a demand that can never fit).
        self.infeasible_rejections = 0
        self._reject_pending: List[Request] = []
        self._shed_marked: List[Request] = []
        scheduler.admit_hook = self._reserve
        scheduler.evict_hook = self._evict
        if self._shed_enabled and self.shed_predicted_tokens is not None:
            # runs BEFORE _reserve (gates added later run first), so a shed
            # veto can never leak a KV reservation
            scheduler.add_admit_gate(self._shed_gate)
        backend.attach(self)

    # ------------------------------------------------------------------ api
    def submit(self, requests: Sequence[Request]) -> None:
        self._check_alive()
        if not self._deadlines_seen:
            self._deadlines_seen = any(r.deadline is not None
                                       for r in requests)
        self._pending = deque(sorted([*self._pending, *requests],
                                     key=lambda r: r.arrival_time))

    # ------------------------------------------------------- crash lifecycle
    def _check_alive(self) -> None:
        if self._crashed:
            raise ReplicaCrashed("serving core is down")

    def inject_crash(self) -> None:
        """Mark this core dead without unwinding it — the next probe, submit,
        or tick raises :class:`ReplicaCrashed` (how a fault schedule or test
        kills a replica between steps)."""
        self._crashed = True

    def crash(self) -> List[Request]:
        """Kill this core and extract every request it was responsible for.

        Crash semantics: all KV on this replica is lost. Running requests'
        reservations and backend residency are released, partial prefill
        progress is discarded (failover is recompute-from-prompt), and the
        prefix cache is cleared cold — its committed blocks point at memory
        that no longer exists, so they must stop being hitable (backends
        drop their fragments via the evict listeners). The allocator object
        itself survives (backends hold references to it); only its contents
        reset. Returns the lost requests, in pending → waiting → running
        order, for the router to fail over."""
        self._crashed = True
        sched = self.scheduler
        lost = [*self._pending, *sched.waiting, *sched.running]
        for r in sched.running:
            self.allocator.free(r.req_id)
            self.backend.release(r)
        for r in lost:
            r.prefilled_tokens = 0
            r.prefill_target = None
        self._pending.clear()
        sched.waiting.clear()
        sched.running.clear()
        self._reject_pending.clear()
        self._shed_marked.clear()
        self.allocator.clear_cache()
        self._hash_memo.clear()
        self._overload_steps = 0
        self._shed_active = False
        return lost

    def restart(self) -> None:
        """Rejoin cold after :meth:`crash`: the core accepts work again with
        an empty cache — exactly a fresh replica, minus construction cost."""
        self._crashed = False

    def decode_ready(self, req: Request) -> bool:
        """True once a request's whole prompt is KV-resident (it may join
        the decode batch). Backends use this to filter ``running``."""
        return req.prefilled_tokens >= self._target(req)

    # -------------------------------------------------------- router probes
    # Read-only observations a multi-replica front-end routes by. None of
    # them mutate request or allocator state: a probed request may well be
    # routed to a different replica. Every probe checks liveness first —
    # probe failure (``ReplicaCrashed``) is the router's failure detector.
    def queue_depth(self) -> int:
        """Unfinished requests this core is responsible for: routed but not
        yet arrived, waiting, and running."""
        self._check_alive()
        return (len(self._pending) + len(self.scheduler.waiting)
                + len(self.scheduler.running))

    def kv_used_blocks(self) -> int:
        """Distinct KV blocks currently referenced (shared blocks once)."""
        self._check_alive()
        return self.allocator.used_blocks

    def kv_pressure(self) -> float:
        """Referenced fraction of the KV budget, in [0, 1]. Unbounded
        allocators report 0.0 — rank those replicas by
        :meth:`kv_used_blocks` instead."""
        self._check_alive()
        if self.allocator.total_blocks >= UNBOUNDED_BLOCKS:
            return 0.0
        return self.allocator.used_blocks / self.allocator.total_blocks

    def predicted_remaining_tokens(
            self, predicted_len: Callable[[Request], float]) -> float:
        """Predicted tokens of work left on this core: for every unfinished
        request it owns, the prompt tokens still to prefill plus
        ``max(predicted_len(req) - tokens_done, 0)`` predicted decode
        tokens. The router's ``predicted_shortest_queue`` policy sums PARS
        scores through this (``predicted_len`` maps a request to its
        predicted output length — typically ``req.score``).

        When iterative re-ranking has refreshed a request's remaining
        estimate (``Request.remaining_est``), the probe reads *that* —
        never the stale arrival score — so routing pressure decays as a
        replica's long requests approach completion, in lockstep with the
        keys its own scheduler ranks by."""
        self._check_alive()
        total = 0.0
        for r in (*self._pending, *self.scheduler.waiting,
                  *self.scheduler.running):
            target = (r.prefill_target if r.prefill_target is not None
                      else self.backend.prefill_total(r))
            total += max(target - r.prefilled_tokens, 0)
            if r.remaining_est is not None:
                total += r.remaining_est
            else:
                total += max(float(predicted_len(r)) - r.tokens_done, 0.0)
        return total

    def prefix_affinity_blocks(self, req: Request) -> int:
        """Committed cached blocks this core could share for ``req``'s
        prompt right now — the router's cache-affinity probe. 0 when prefix
        caching is off. Deliberately unmemoized (unlike
        :meth:`_prefix_hashes`): the request may be routed elsewhere, and a
        stale memo entry on a non-chosen replica would never be reclaimed."""
        self._check_alive()
        if not self.prefix_caching:
            return 0
        chain = prefix_chunk_hashes(self.backend.prefix_tokens(req),
                                    self.allocator.block_size)
        cap = (max(self.backend.prefill_total(req) - 1, 0)
               // self.allocator.block_size)
        return self.allocator.cached_prefix_blocks(chain[:cap])

    def next_event_time(self) -> float:
        """When this core next has something to do, in its clock's
        timebase: now if scheduled work exists, the first pending arrival
        otherwise, ``+inf`` when fully drained. The router advances the
        replica with the earliest next event (discrete-event order across
        replicas)."""
        self._check_alive()
        if self.scheduler.has_work:
            return self.clock.now()
        if self._pending:
            return max(self.clock.now(), self._pending[0].arrival_time)
        return float("inf")

    def _target(self, req: Request) -> int:
        """The request's frozen prefill total: snapshotted at admission so a
        backend total that folds in recompute work (the simulator charges
        prompt + generated tokens after preemption) doesn't drift while the
        request decodes."""
        if req.prefill_target is None:
            req.prefill_target = self.backend.prefill_total(req)
        return req.prefill_target

    def _prefix_hashes(self, req: Request) -> List[int]:
        """The request's shareable chunk-hash chain, capped so at least the
        last prompt position is always recomputed (the backend needs its
        logits to emit the first output token). Empty when caching is off."""
        if not self.prefix_caching:
            return []
        chain = self._hash_memo.get(req.req_id)
        if chain is None:
            chain = prefix_chunk_hashes(self.backend.prefix_tokens(req),
                                        self.allocator.block_size)
            self._hash_memo[req.req_id] = chain
        cap = max(self._target(req) - 1, 0) // self.allocator.block_size
        return chain[:cap]

    def _admission_need(self, req: Request) -> int:
        """KV tokens an admission must reserve. Full mode: the backend's
        worst-case demand. Incremental mode: the prompt (``prefill_target``)
        plus one decode block — decode growth is paid step-by-step."""
        need = self.backend.kv_demand(req)
        if self.kv_reservation == "incremental":
            need = min(self._target(req) + self.allocator.block_size, need)
        return need

    # ---------------------------------------------------------------- hooks
    def _reserve(self, req: Request) -> bool:
        """Scheduler admission gate: reserve KV blocks or keep the request
        in W (memory back-pressure, identical in both execution modes).

        Under ``kv_reservation="full"`` the *full* demand is reserved up
        front even under chunked prefill — a half-prefilled request can
        never stall on blocks its own decode phase needs. Under
        ``"incremental"`` only the prompt + first decode block is reserved
        (``_grow_for_decode`` pays for the rest). With prefix caching, the
        leading blocks that match a committed cached chain are shared
        rather than newly claimed, and the request starts prefill at the
        cached offset."""
        need = self._admission_need(req)
        hashes = self._prefix_hashes(req)
        if self.allocator.blocks_for(need) > self.allocator.total_blocks:
            # Certain infeasibility: the request's own block table would
            # exceed the whole cache — no amount of draining (or prefix
            # sharing, which reduces new claims but not table length) can
            # ever admit it. Deferring would wedge the loop forever; mark it
            # for terminal rejection (swept after this scheduling cycle).
            req.gate_rejections += 1
            self._reject_pending.append(req)
            return False
        if not self.allocator.can_allocate(need, hashes):
            req.gate_rejections += 1
            return False
        shared = self.allocator.allocate(req.req_id, need, hashes)
        if self.kv_reservation == "incremental":
            # None → 0 marks "incremental accounting active" (metrics stay
            # NaN-safe for full-reservation runs); preserved across
            # preemption re-admissions like ``cached_prefix_tokens``
            req.grow_failures = req.grow_failures or 0
            req.grow_preemptions = req.grow_preemptions or 0
        if self._rerank_enabled:
            # same None → 0 convention for the re-rank preemption counter
            req.rerank_preemptions = req.rerank_preemptions or 0
        if self.prefix_caching:
            cached = shared * self.allocator.block_size
            if cached:
                req.prefilled_tokens = cached
            # None → int marks "caching was on for this request" (metrics
            # stay NaN-safe when it is off); accumulates across preemption
            # re-admissions so tokens-saved reflects every avoided prefill
            req.cached_prefix_tokens = (req.cached_prefix_tokens or 0) + cached
        return True

    def _evict(self, req: Request) -> None:
        """Preemption eviction: blocks and backend residency come back.
        (The scheduler resets ``prefilled_tokens`` — a half-prefilled victim
        re-prefills from offset 0 on re-admission.)"""
        self.allocator.free(req.req_id)
        self.backend.release(req)

    def _retire(self, now: float) -> None:
        for r in self.scheduler.retire_finished(now):
            self.allocator.free(r.req_id)
            self.backend.release(r)
            self._hash_memo.pop(r.req_id, None)
            self.finished.append(r)

    # ------------------------------------------------ drops (terminal exits)
    def _drop(self, req: Request, now: float, state: RequestState,
              reason: str) -> None:
        """Terminal non-success exit: the request leaves the system for good.
        Any held resources are released (no-ops for never-admitted work)."""
        self.allocator.free(req.req_id)
        self.backend.release(req)
        req.state = state
        req.drop_reason = reason
        req.finish_time = now
        self._hash_memo.pop(req.req_id, None)
        self.dropped.append(req)

    def _drop_from_waiting(self, reqs: List[Request], now: float,
                           state: RequestState, reason: str) -> None:
        ids = {id(r) for r in reqs}
        self.scheduler.waiting = [r for r in self.scheduler.waiting
                                  if id(r) not in ids]
        for r in reqs:
            self._drop(r, now, state, reason)

    def _sweep_marked(self, now: float) -> None:
        """Finalize requests the admission gates marked this cycle (they
        stayed in W through the scan; the scan must not mutate the queue it
        iterates)."""
        if self._reject_pending:
            self.infeasible_rejections += len(self._reject_pending)
            self._drop_from_waiting(self._reject_pending, now,
                                    RequestState.REJECTED, "kv-infeasible")
            self._reject_pending = []
        if self._shed_marked:
            self.shed_count += len(self._shed_marked)
            self._drop_from_waiting(self._shed_marked, now,
                                    RequestState.SHED, "overload")
            self._shed_marked = []

    # ------------------------------------------------------------- deadlines
    def _estimate_len(self, req: Request) -> Optional[float]:
        """Best current output-length estimate: the refreshed remaining
        estimate, else the predictor score. ``None`` when the run has no
        basis (unscored under fcfs) — estimate-gated decisions then skip."""
        if req.remaining_est is not None:
            return req.remaining_est
        if req.scored:
            return req.score
        return None

    def _enforce_deadlines(self, now: float) -> None:
        """Cancel past-deadline work (terminal ``CANCELLED``): in-flight
        requests free their blocks and backend residency mid-stream; waiting
        requests are also cancelled *pre-admission* when
        ``deadline_time_per_token`` says their predicted service time
        already overruns the deadline — admitting them would only burn
        prefill the SLO can never credit."""
        expired_r = [r for r in self.scheduler.running
                     if r.deadline is not None and now > r.deadline]
        for r in expired_r:
            self.scheduler.running.remove(r)
            self._drop(r, now, RequestState.CANCELLED, "deadline")
        tpt = self.deadline_time_per_token
        expired_w = []
        for r in self.scheduler.waiting:
            if r.deadline is None:
                continue
            if now > r.deadline:
                expired_w.append(r)
            elif tpt is not None:
                est = self._estimate_len(r)
                if est is not None and now + tpt * est > r.deadline:
                    expired_w.append(r)
        if expired_w:
            self._drop_from_waiting(expired_w, now, RequestState.CANCELLED,
                                    "deadline")
        self.deadline_cancels += len(expired_r) + len(expired_w)

    # ---------------------------------------------------------- load shedding
    def _shed_victim_key(self, r: Request, now: float) -> Tuple:
        """Shed-preference ordering; victims are taken from the *end* of a
        list sorted ascending by this key. Class-aware: a waiting request
        whose TTFT SLO is already blown sheds first (its tokens can never
        count toward goodput again), then lower ``Request.priority`` classes
        before higher, then the scheduler's own rank (worst-ranked last).
        Without class/SLO annotations every component but the rank is
        constant, reducing exactly to the historical worst-ranked-tail
        ordering."""
        ttft_blown = (r.slo_ttft_s is not None
                      and r.first_token_time is None
                      and now - r.arrival_time > r.slo_ttft_s)
        return (1 if ttft_blown else 0, -r.priority,
                self.scheduler._sort_key(r))

    def _update_shedding(self, now: float) -> None:
        """Sustained-overload detection + tail shedding. Overload = queue
        depth over ``shed_queue_depth`` and/or KV pressure over
        ``shed_kv_pressure`` for ``shed_sustain_steps`` *consecutive* steps
        (a one-step burst never sheds). While active, the least-worth-keeping
        non-boosted waiting requests are shed (:meth:`_shed_victim_key`:
        blown-SLO first, then low priority classes, then worst rank): down
        to the queue-depth target when that trigger fired, one per step
        under pure KV pressure. Boosted (starvation-pinned) requests are
        never shed."""
        over_queue = (self.shed_queue_depth is not None
                      and len(self.scheduler.waiting) > self.shed_queue_depth)
        over_kv = (self.shed_kv_pressure is not None
                   and self.kv_pressure() >= self.shed_kv_pressure)
        self._overload_steps = (self._overload_steps + 1
                                if (over_queue or over_kv) else 0)
        self._shed_active = self._overload_steps >= self.shed_sustain_steps
        if not self._shed_active:
            return
        sheddable = sorted((r for r in self.scheduler.waiting
                            if not r.boosted),
                           key=lambda r: self._shed_victim_key(r, now))
        if over_queue:
            n = len(self.scheduler.waiting) - self.shed_queue_depth
        else:
            n = 1
        victims = sheddable[len(sheddable) - min(n, len(sheddable)):]
        if victims:
            self.shed_count += len(victims)
            self._drop_from_waiting(victims, now, RequestState.SHED,
                                    "overload")

    def _shed_gate(self, req: Request) -> bool:
        """Admission gate (composed via ``Scheduler.add_admit_gate``, so it
        runs before the KV hook reserves anything): while overload shedding
        is active, refuse work predicted longer than
        ``shed_predicted_tokens`` — under overload, admitting a long request
        delays every queued short one behind it. Class-aware: requests from
        priority > 0 classes are exempt — their SLO is what shedding exists
        to protect, so the gate only turns away best-effort traffic."""
        if not self._shed_active or req.boosted or req.priority > 0:
            return True
        est = self._estimate_len(req)
        if est is not None and est >= self.shed_predicted_tokens:
            self._shed_marked.append(req)
            return False
        return True

    # ----------------------------------------------------------------- loop
    def _plan_chunks(self) -> List[PrefillChunk]:
        """Plan this step's prefill work under the chunk-token budget.

        Walks ``running`` in admission order (oldest prefill first, so
        earlier arrivals reach their first token sooner). A request whose
        whole remainder fits the remaining budget takes it and leaves the
        rest for later requests (Sarathi-style chunk packing). A *partial*
        take — splitting a prompt mid-stream — is only allowed as the
        step's first planned chunk, where it gets the full budget: that
        keeps every chunk length in {whole padded prompts, remainders of
        them, the budget itself}, so the real engine's jitted dispatch
        shapes stay inside the warmed (bucket ∪ chunk) grid instead of
        fragmenting into arbitrary leftover lengths. A request skipped for
        that reason is head-of-line next step, so it cannot starve.

        With no budget configured every prefilling request gets its full
        remainder in one chunk, which is exactly the historical
        prefill-to-completion step.
        """
        budget = self.prefill_chunk_tokens or float("inf")
        chunks: List[PrefillChunk] = []
        for r in self.scheduler.running:
            if budget <= 0:
                break
            remaining = self._target(r) - r.prefilled_tokens
            if remaining <= 0:
                continue
            if remaining <= budget:
                take = remaining
            elif not chunks:
                take = int(budget)
            else:
                continue        # no mid-pack partials (bounded shapes)
            chunks.append((r, r.prefilled_tokens, r.prefilled_tokens + take))
            budget -= take
        return chunks

    # ------------------------------------------------- incremental reservation
    def _grow_victim(self, req: Request) -> Optional[Request]:
        """Deterministic preemption fallback for a denied decode-time grow:
        the lowest-ranked *other* running request still holding blocks —
        non-boosted before boosted, then worst policy key, req_id as the
        final tiebreak so both execution modes pick the same victim."""
        pool = [v for v in self.scheduler.running
                if v is not req and self.allocator.reserved(v.req_id)]
        if not pool:
            return None
        return max(pool, key=lambda v: (not v.boosted,
                                        self.scheduler.policy.key(v),
                                        v.req_id))

    def _preempt_for_grow(self, victim: Request) -> None:
        """Evict ``victim`` back to W with recompute semantics (mirrors
        ``Scheduler._preempt``: partial KV residency is lost, re-admission
        re-prefills from offset 0 and re-snapshots the prefill target)."""
        self.scheduler.running.remove(victim)
        victim.state = RequestState.WAITING
        victim.preempt_count += 1
        victim.grow_preemptions = (victim.grow_preemptions or 0) + 1
        self.scheduler._note_demotion(victim)   # starvation bound applies too
        victim.prefilled_tokens = 0
        victim.prefill_target = None
        self._evict(victim)
        self.scheduler.waiting.append(victim)

    def _grow_for_decode(self) -> None:
        """Incremental mode: before a decode iteration, grow every
        decode-ready request's reservation to cover the KV row the next
        token writes (``prefill_target + tokens_done + 1`` tokens, capped
        at the backend's full demand — one new block every
        ``block_size`` steps). A denied grow preempts the lowest-ranked
        other running request and retries; a request that cannot be grown
        even with the batch to itself can never finish, which is a genuine
        capacity error, not back-pressure."""
        for r in list(self.scheduler.running):
            if r.state is not RequestState.RUNNING or not self.decode_ready(r):
                continue
            need = min(self._target(r) + r.tokens_done + 1,
                       self.backend.kv_demand(r))
            while True:
                delta = (self.allocator.blocks_for(need)
                         - self.allocator.reserved(r.req_id))
                if delta <= 0 or self.allocator.grow(r.req_id, delta):
                    break
                r.grow_failures = (r.grow_failures or 0) + 1
                if self.allocator.free_blocks >= delta:
                    # Denied despite sufficient free capacity: the denial is
                    # not memory pressure (an injected grow storm), so
                    # evicting victims cannot help — self-preempt with
                    # recompute semantics and retry on re-admission.
                    # Unreachable without faults: ``grow`` fails only when
                    # ``delta`` exceeds free (incl. LRU-parked) blocks.
                    self._preempt_for_grow(r)
                    break
                victim = self._grow_victim(r)
                if victim is None:
                    if (self.allocator.blocks_for(need)
                            > self.allocator.total_blocks):
                        raise MemoryError(
                            f"KV budget cannot sustain request {r.req_id} "
                            f"even alone: needs "
                            f"{self.allocator.blocks_for(need)} "
                            f"blocks of {self.allocator.block_size}, cache "
                            f"has {self.allocator.total_blocks} "
                            f"({self.allocator.free_blocks} free)")
                    # Transient denial with nobody to evict while feasible
                    # alone — same storm-shaped cause, same recovery.
                    self._preempt_for_grow(r)
                    break
                self._preempt_for_grow(victim)

    def _maybe_rerank(self, now: float) -> None:
        """Fire a priority-key refresh when the configured cadence is due —
        *before* this cycle's ``schedule`` call, so the refreshed ranks
        drive its sort, admission order, and preemption victim choice."""
        if not self._rerank_enabled:
            return
        due = (self.rerank_every_steps is not None
               and self._steps_since_rerank >= self.rerank_every_steps)
        if self.rerank_interval is not None:
            if self._last_rerank_t is None:
                self._last_rerank_t = now      # cadence origin: first step
            elif now - self._last_rerank_t >= self.rerank_interval:
                due = True
        if due:
            self.scheduler.rerank(now, floor=self.rerank_floor)
            self._steps_since_rerank = 0
            self._last_rerank_t = now

    @property
    def rerank_count(self) -> int:
        """Priority-key refreshes performed so far (scheduler-owned)."""
        return self.scheduler.rerank_count

    def step(self, now: float) -> float:
        """One mixed serving cycle: fault hook → deadlines/shedding → admit
        → prefill ≤ chunk tokens → one decode token for every fully
        prefilled running request → retire. Every fault-tolerance stage is
        a no-op (a flag test) unless its feature was configured."""
        self.step_count += 1
        if self.fault_hook is not None:
            self.fault_hook(self, now)
        if self._deadlines_seen:
            self._enforce_deadlines(now)
        if self._shed_enabled:
            self._update_shedding(now)
        self._maybe_rerank(now)
        self._steps_since_rerank += 1
        self.scheduler.schedule(now)
        if self._reject_pending or self._shed_marked:
            self._sweep_marked(now)
        chunks = self._plan_chunks()
        if chunks:
            now = self.backend.prefill(chunks, now)
            for req, _start, end in chunks:
                req.prefilled_tokens = end
                if self.prefix_caching and end >= self._target(req):
                    # prompt fully resident: its content-named blocks become
                    # hitable for later admissions (the real backend stored
                    # the matching KV fragments during this prefill call)
                    self.allocator.commit(req.req_id)
            self._retire(now)            # true_length == 1 finishes at prefill
        if self.scheduler.running:
            if self.kv_reservation == "incremental":
                self._grow_for_decode()
            if self.scheduler.running:   # grow preemption may have drained R
                now = self.backend.decode(now)
            self._retire(now)
        return now

    def tick(self, *,
             on_step: Optional[Callable[["ServingCore", float], None]] = None,
             ) -> Optional[float]:
        """One run-loop iteration — the step-one-replica API.

        Delivers due arrivals from the pending deque, takes one serving
        :meth:`step` if there is scheduled work (or fast-forwards the clock
        to the next arrival if not), and returns the core's clock time
        afterwards — ``None`` when the core is fully drained. ``run()`` is a
        loop over this; the multi-replica router interleaves ``tick()``
        calls across replicas instead, so a front-end drives N cores
        without duplicating any of the loop's arrival/progress semantics.

        Raises ``MemoryError`` when the core is wedged: the KV gate rejects
        every waiting request, nothing is executing, and no future arrival
        exists that could drain first (admission depends only on allocator
        state, so a wedge with an empty pending deque is permanent). With
        gate-time infeasibility rejection this is a defensive dead path —
        a request that can never fit exits terminally ``REJECTED`` at its
        first admission scan instead of wedging the loop."""
        self._check_alive()
        if not (self._pending or self.scheduler.has_work):
            return None
        now = self.clock.now()
        arrived = []
        while self._pending and self._pending[0].arrival_time <= now:
            arrived.append(self._pending.popleft())
        if arrived:
            self.scheduler.add_requests(arrived)
        if not self.scheduler.has_work:
            self.clock.wait_until(self._pending[0].arrival_time)
            return self.clock.now()
        running_before = bool(self.scheduler.running)
        finished_before = len(self.finished)
        dropped_before = len(self.dropped)
        new_now = self.step(now)
        if on_step is not None:
            on_step(self, new_now)
        progressed = (new_now != now or running_before
                      or self.scheduler.running
                      or len(self.finished) > finished_before
                      or len(self.dropped) > dropped_before)
        if not progressed:
            # KV gate rejected everything and nothing is executing
            if self._pending:
                self.clock.wait_until(self._pending[0].arrival_time)
                return self.clock.now()

            # effective demand: blocks a request must newly claim, after
            # subtracting the cached-prefix blocks it would share — with
            # caching on, the cheapest-to-admit request is the one with
            # the smallest *non-shared* footprint, not the smallest
            # prompt (its full demand may exceed what admission needs)
            def _new_blocks(r: Request) -> int:
                return (self.allocator.blocks_for(self._admission_need(r))
                        - self.allocator.cached_prefix_blocks(
                            self._prefix_hashes(r)))
            smallest = min(self.scheduler.waiting, key=_new_blocks)
            tokens = self._admission_need(smallest)
            shared = self.allocator.cached_prefix_blocks(
                self._prefix_hashes(smallest))
            cached_note = (f" ({shared} reusable from the prefix cache)"
                           if shared else "")
            raise MemoryError(
                f"KV budget can never admit remaining requests: request "
                f"{smallest.req_id} has the smallest demand, "
                f"{tokens} tokens = {self.allocator.blocks_for(tokens)} "
                f"blocks of {self.allocator.block_size}{cached_note}, "
                f"but the cache only has {self.allocator.total_blocks} "
                f"blocks ({self.allocator.free_blocks} free)")
        self.clock.wait_until(new_now)
        return new_now

    def run(self, *, max_time: float = float("inf"), log_every: float = 0.0,
            log_fn=print,
            on_step: Optional[Callable[["ServingCore", float], None]] = None,
            ) -> List[Request]:
        """Serve everything submitted; returns the finished requests.

        ``on_step(core, now)`` fires after every serving cycle — benchmark
        probes sample batch occupancy / allocator state through it without
        patching the loop."""
        last_log = 0.0
        total = len(self._pending) + len(self.finished) + \
            len(self.scheduler.waiting) + len(self.scheduler.running)
        while self._pending or self.scheduler.has_work:
            if self.clock.now() >= max_time:
                break
            new_now = self.tick(on_step=on_step)
            if new_now is None:
                break
            if log_every and new_now - last_log > log_every:
                last_log = new_now
                log_fn(f"[core t={new_now:8.2f}s] "
                       f"running={len(self.scheduler.running)} "
                       f"waiting={len(self.scheduler.waiting)} "
                       f"finished={len(self.finished)}/{total}")
        self._retire(self.clock.now())
        return self.finished
