"""Unified serving step loop shared by the real JAX engine and the simulator.

Both execution modes used to hand-roll their own loop, and the two drifted:
the engine enforced the KV budget by reaching into the scheduler's queues,
while the simulator ignored the ``BlockAllocator`` entirely. ``ServingCore``
owns the one canonical cycle —

    arrival delivery → KV-aware admission → prefill → decode → retirement

— parameterized by an :class:`ExecutionBackend` (the jitted JAX engine or the
calibrated cost model) and a :class:`Clock` (wall time or discrete-event
time). KV back-pressure lives in the scheduling path itself: the core installs
an ``admit_hook`` on the scheduler that reserves cache blocks at admission
time, so a request that doesn't fit simply stays in W — no queue surgery, in
either mode. Preemption evictions release their reservation through the
scheduler's ``evict_hook`` the same way.

New serving behavior (chunked prefill, prefix caching, multi-replica
dispatch) lands here once and both modes inherit it.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, List, Optional, Protocol, Sequence

from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.kv_cache import BlockAllocator


class Clock(Protocol):
    def now(self) -> float: ...
    def wait_until(self, t: float) -> None: ...


class WallClock:
    """Real time, origin at construction."""

    def __init__(self) -> None:
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def wait_until(self, t: float) -> None:
        # short sleep, then re-check: arrivals are delivered by the run loop
        if t > self.now():
            time.sleep(min(1e-4, max(t - self.now(), 0.0)))


class VirtualClock:
    """Discrete-event time: advances only when the loop says so."""

    def __init__(self, t: float = 0.0) -> None:
        self.t = t

    def now(self) -> float:
        return self.t

    def wait_until(self, t: float) -> None:
        self.t = max(self.t, t)


class ExecutionBackend(Protocol):
    """What a backend must provide; see ``RealBackend`` / ``SimBackend``."""

    def attach(self, core: "ServingCore") -> None: ...

    def kv_demand(self, req: Request) -> int:
        """Tokens of KV cache this request will occupy while resident."""
        ...

    def prefill(self, admitted: Sequence[Request], now: float) -> float:
        """Process newly admitted requests; returns the updated time."""
        ...

    def decode(self, now: float) -> float:
        """Advance every running request one token; returns the updated time."""
        ...

    def release(self, req: Request) -> None:
        """Free backend residency (slot, …) for a retired/evicted request."""
        ...


class ServingCore:
    """The single KV-aware step loop behind the engine and the simulator."""

    def __init__(self, scheduler: Scheduler, backend: ExecutionBackend, *,
                 allocator: Optional[BlockAllocator] = None,
                 clock: Optional[Clock] = None) -> None:
        self.scheduler = scheduler
        self.backend = backend
        self.allocator = allocator or BlockAllocator.unbounded()
        self.clock: Clock = clock or WallClock()
        self.finished: List[Request] = []
        self._pending: Deque[Request] = deque()
        scheduler.admit_hook = self._reserve
        scheduler.evict_hook = self._evict
        backend.attach(self)

    # ------------------------------------------------------------------ api
    def submit(self, requests: Sequence[Request]) -> None:
        self._pending = deque(sorted([*self._pending, *requests],
                                     key=lambda r: r.arrival_time))

    # ---------------------------------------------------------------- hooks
    def _reserve(self, req: Request) -> bool:
        """Scheduler admission gate: reserve KV blocks or keep the request
        in W (memory back-pressure, identical in both execution modes)."""
        need = self.backend.kv_demand(req)
        if not self.allocator.can_allocate(need):
            return False
        self.allocator.allocate(req.req_id, need)
        return True

    def _evict(self, req: Request) -> None:
        """Preemption eviction: blocks and backend residency come back."""
        self.allocator.free(req.req_id)
        self.backend.release(req)

    def _retire(self, now: float) -> None:
        for r in self.scheduler.retire_finished(now):
            self.allocator.free(r.req_id)
            self.backend.release(r)
            self.finished.append(r)

    # ----------------------------------------------------------------- loop
    def step(self, now: float) -> float:
        """One serving cycle: admit → prefill → decode → retire."""
        admitted = self.scheduler.schedule(now)
        if admitted:
            now = self.backend.prefill(admitted, now)
            self._retire(now)            # true_length == 1 finishes at prefill
        if self.scheduler.running:
            now = self.backend.decode(now)
            self._retire(now)
        return now

    def run(self, *, max_time: float = float("inf"), log_every: float = 0.0,
            log_fn=print) -> List[Request]:
        """Serve everything submitted; returns the finished requests."""
        last_log = 0.0
        total = len(self._pending) + len(self.finished) + \
            len(self.scheduler.waiting) + len(self.scheduler.running)
        while self._pending or self.scheduler.has_work:
            now = self.clock.now()
            if now >= max_time:
                break
            arrived = []
            while self._pending and self._pending[0].arrival_time <= now:
                arrived.append(self._pending.popleft())
            if arrived:
                self.scheduler.add_requests(arrived)
            if not self.scheduler.has_work:
                self.clock.wait_until(self._pending[0].arrival_time)
                continue
            running_before = bool(self.scheduler.running)
            finished_before = len(self.finished)
            new_now = self.step(now)
            progressed = (new_now != now or running_before
                          or self.scheduler.running
                          or len(self.finished) > finished_before)
            if not progressed:
                # KV gate rejected everything and nothing is executing
                if self._pending:
                    self.clock.wait_until(self._pending[0].arrival_time)
                    continue
                need = min(self.backend.kv_demand(r)
                           for r in self.scheduler.waiting)
                raise MemoryError(
                    f"KV budget can never admit remaining requests: min "
                    f"demand {self.allocator.blocks_for(need)} blocks, "
                    f"capacity {self.allocator.total_blocks}")
            self.clock.wait_until(new_now)
            if log_every and new_now - last_log > log_every:
                last_log = new_now
                log_fn(f"[core t={new_now:8.2f}s] "
                       f"running={len(self.scheduler.running)} "
                       f"waiting={len(self.scheduler.waiting)} "
                       f"finished={len(self.finished)}/{total}")
        self._retire(self.clock.now())
        return self.finished
