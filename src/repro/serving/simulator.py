"""Discrete-event continuous-batching simulation: ``SimBackend`` + ServingCore.

Drives the *same* ``ServingCore`` step loop (and the same
``repro.core.scheduler.Scheduler``) the real engine uses, against a calibrated
iteration-time model, so 2000-request bursts and arrival-rate sweeps (paper
§IV-D) run in milliseconds on CPU. Semantics match vLLM-style iteration-level
batching:

* each iteration, every running request whose prompt is fully KV-resident
  decodes exactly one token;
* prefill work is folded into the iteration in which it happens (vLLM's
  mixed prefill/decode steps): the core hands this backend chunks, the
  backend accumulates their token count, and the next ``decode`` charges
  ``prefill_per_token_s`` for them. With chunking off a prompt is one chunk
  and this reduces to the historical admit-then-prefill-whole-prompt cost;
  with ``prefill_chunk_tokens`` set, a long prompt spreads its prefill cost
  over many cheap iterations while co-resident decodes keep advancing —
  ``CostModel.iteration_time`` already models exactly this mixed step.
* iteration time = base + per-decoding-seq cost + per-prefill-token cost,
  the standard two-parameter decode-latency model for batched LLM serving.

Because admission goes through the core's KV gate, a simulated run under a
constrained ``kv_blocks`` budget defers admissions exactly like the real
engine does — by default the budget is unbounded, preserving the paper's
memory-unconstrained sweep setup.

Default constants approximate a 7B-class model on an A100 (the paper's
testbed scale): 25 ms base, 0.15 ms per running request per step, 0.5 ms per
prefill token. Absolute values shift all policies equally; the *relative*
policy gaps the paper reports are driven by queueing, not by the constants.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

from repro.core.predictor.tokenizer import HashTokenizer
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.config import ServingConfig, resolve_config
from repro.serving.core import PrefillChunk, ServingCore, VirtualClock
from repro.serving.kv_cache import BlockAllocator
from repro.serving.metrics import LatencyReport, RunCounters, report
from repro.serving.router import ReplicaRouter


@dataclass(frozen=True)
class CostModel:
    iter_base_s: float = 0.025       # fixed per-iteration cost
    per_seq_s: float = 0.00015       # marginal cost per running sequence
    prefill_per_token_s: float = 0.0005

    def iteration_time(self, batch_size: int, prefill_tokens: int) -> float:
        return (self.iter_base_s + self.per_seq_s * batch_size
                + self.prefill_per_token_s * prefill_tokens)


class SimBackend:
    """Cost-model execution: prefill records the chunked-in tokens, decode
    charges one mixed iteration and advances every prompt-resident request."""

    # same stable word-hash scheme the real engine's HashTokenizer uses, so
    # textually shared prompt prefixes map to shared token-id prefixes in
    # both execution modes (cross-backend prefix-hit equivalence is tested)
    _TOK = HashTokenizer(vocab_size=2048, max_len=1 << 30)

    def __init__(self, cost: CostModel = CostModel()) -> None:
        self.cost = cost
        self._prefill_tokens = 0
        self.core: Optional[ServingCore] = None

    def attach(self, core: ServingCore) -> None:
        self.core = core

    def kv_demand(self, req: Request) -> int:
        # forced-length protocol: residency is prompt + full completion
        return req.prompt_len + req.true_length

    def prefill_total(self, req: Request) -> int:
        # recompute semantics: a request re-admitted after preemption — or
        # re-dispatched after a replica crash (failover) — re-prefills its
        # prompt plus everything it had already generated
        recompute = req.preempt_count or (req.failovers or 0)
        return req.prompt_len + (req.tokens_done if recompute else 0)

    def prefix_tokens(self, req: Request) -> Sequence[int]:
        """Prefix-sharing stream: the prompt's word-hash ids, truncated to
        the request's declared ``prompt_len`` (the unit the simulator
        charges prefill in). Prompts with fewer words than ``prompt_len``
        can only share up to their word count — the synthetic tail is not
        content, so it is never cached."""
        return self._TOK.encode(req.prompt)[:req.prompt_len]

    def prefill(self, chunks: Sequence[PrefillChunk], now: float) -> float:
        # cost is charged by the decode phase of the same mixed iteration
        self._prefill_tokens += sum(end - start for _r, start, end in chunks)
        return now

    def decode(self, now: float) -> float:
        running = self.core.scheduler.running
        ready = [r for r in running if self.core.decode_ready(r)]
        if not ready and not self._prefill_tokens:
            return now                # nothing resident and nothing chunked
        now += self.cost.iteration_time(len(ready), self._prefill_tokens)
        self._prefill_tokens = 0
        for r in ready:
            r.tokens_done += 1
            if r.first_token_time is None:
                r.first_token_time = now
            if self.core.record_token_times:
                r.token_times.append(now)
        return now

    def release(self, req: Request) -> None:
        pass                          # no slot residency to free


def clone_requests(requests: Sequence[Request]) -> List[Request]:
    """Fresh lifecycle records for re-running one workload under another
    policy: workload identity (prompt, lengths, arrival, deadline,
    tenant/class/SLO annotations) carries over; run state (timestamps,
    scores, queue flags) resets."""
    return [Request(r.req_id, r.prompt, r.arrival_time, r.prompt_len,
                    r.true_length, deadline=r.deadline, tenant=r.tenant,
                    priority_class=r.priority_class, priority=r.priority,
                    slo_ttft_s=r.slo_ttft_s, slo_itl_s=r.slo_itl_s)
            for r in requests]


def make_sim_core(scheduler: Scheduler, *, cost: CostModel = CostModel(),
                  kv_blocks: Optional[int] = None, block_size: int = 16,
                  config: Optional[ServingConfig] = None,
                  **core_kw) -> ServingCore:
    """One fresh simulated serving core: its own allocator (``kv_blocks``
    bounded, or unbounded), ``SimBackend`` and ``VirtualClock``. Behaviour
    comes from ``config`` (or equivalently loose core keywords — chunking,
    caching, reservation mode, re-ranking cadence, deadlines, shedding, …,
    folded into a :class:`ServingConfig` here) — one construction path for
    every sim entry point, so new core features never need plumbing here
    again."""
    allocator = (BlockAllocator(kv_blocks, block_size) if kv_blocks
                 else BlockAllocator.unbounded(block_size))
    return ServingCore(scheduler, SimBackend(cost), allocator=allocator,
                       clock=VirtualClock(),
                       config=resolve_config(config, core_kw))


def simulate(requests: Sequence[Request], scheduler: Scheduler, *,
             cost: CostModel = CostModel(), max_time: float = 1e7,
             kv_blocks: Optional[int] = None, block_size: int = 16,
             faults=None, on_step=None, **core_kw) -> List[Request]:
    """Run to completion; returns the finished requests (with timestamps).
    Terminally dropped requests (deadline cancels, shed, rejected) are NOT
    in the return — single-core callers that enable those features should
    build the core via :func:`make_sim_core` and read ``core.dropped``.

    ``kv_blocks`` bounds the KV cache (in ``block_size``-token blocks);
    ``None`` keeps the historical memory-unbounded behaviour.
    Every extra keyword forwards to ``ServingCore``: notably
    ``prefill_chunk_tokens`` (mixed prefill/decode iterations),
    ``prefix_caching`` (share KV blocks across common prompt prefixes — a
    cache-hit admission only charges the non-shared suffix),
    ``kv_reservation="incremental"`` (admit on prompt + one decode block,
    grow per step), ``rerank_interval`` / ``rerank_every_steps``
    (iterative re-ranking), and the fault-tolerance knobs
    (``deadline_time_per_token``, ``shed_queue_depth``, …).
    ``faults`` — a :class:`~repro.serving.faults.FaultSchedule` to attach
    (arrival skew applied to ``requests`` in place, per-step faults hooked
    onto the core)."""
    core = make_sim_core(scheduler, cost=cost, kv_blocks=kv_blocks,
                         block_size=block_size, **core_kw)
    if faults is not None:
        faults.skew_arrivals(requests)
        faults.attach_core(core)
    core.submit(requests)
    return core.run(max_time=max_time, on_step=on_step)


def make_sim_replicas(n: int, policy_factory: Callable[[], object], *,
                      cost: CostModel = CostModel(),
                      kv_blocks: Optional[int] = None, block_size: int = 16,
                      max_batch: int = 16,
                      starvation_threshold: float = 120.0,
                      preemption: bool = False,
                      **core_kw) -> List[ServingCore]:
    """N independent sim replicas: each gets a fresh scheduler (via
    ``policy_factory`` — a zero-arg callable so stateful scorers are not
    accidentally shared), its own ``kv_blocks``-bounded allocator, its own
    ``SimBackend`` and ``VirtualClock``. Replicas share *nothing*; the
    router is the only thing that sees them together. Extra keywords
    forward to each ``ServingCore`` (chunking, caching, re-ranking,
    deadlines, shedding, …)."""
    cores = []
    for _ in range(n):
        sched = Scheduler(policy=policy_factory(), max_batch=max_batch,
                          starvation_threshold=starvation_threshold,
                          preemption=preemption)
        cores.append(make_sim_core(sched, cost=cost, kv_blocks=kv_blocks,
                                   block_size=block_size, **core_kw))
    return cores


def simulate_replicas(requests: Sequence[Request], *, n_replicas: int,
                      policy_factory: Callable[[], object],
                      routing: str = "round_robin",
                      predicted_len=None, seed: int = 0,
                      max_failovers: int = 3,
                      failover_backoff_s: float = 0.5,
                      affinity_escape_after: Optional[int] = None,
                      faults=None,
                      **replica_kw) -> ReplicaRouter:
    """Multi-replica discrete-event run: build ``n_replicas`` fresh sim
    replicas (``replica_kw`` forwards to :func:`make_sim_replicas`), route
    ``requests`` across them with the ``routing`` policy, and drive
    everything to completion. Returns the router — finished requests,
    per-request ``assignments``, ``all_dropped``, and ``report()`` live
    there. ``faults`` — a :class:`~repro.serving.faults.FaultSchedule`
    wired onto the router (per-replica crash/grow faults, restart
    scheduling, arrival skew); the failover knobs
    (``max_failovers`` / ``failover_backoff_s`` / ``affinity_escape_after``)
    forward to :class:`~repro.serving.router.ReplicaRouter`. Costs scale
    with total tokens, not wall time, so ~10^5-request traces sweep all
    routing policies in seconds-to-minutes on CPU."""
    router = ReplicaRouter(make_sim_replicas(n_replicas, policy_factory,
                                             **replica_kw),
                           policy=routing, predicted_len=predicted_len,
                           seed=seed, max_failovers=max_failovers,
                           failover_backoff_s=failover_backoff_s,
                           affinity_escape_after=affinity_escape_after)
    if faults is not None:
        faults.skew_arrivals(requests)
        faults.attach_router(router)
    router.submit(requests)
    router.run()
    return router


def run_policy(requests: Sequence[Request], policy, *, max_batch: int = 16,
               continuous: bool = True, cost: CostModel = CostModel(),
               starvation_threshold: float = 120.0,
               preemption: bool = False, max_preemptions: int = 2,
               kv_blocks: Optional[int] = None,
               config: Optional[ServingConfig] = None,
               rerank_interval: Optional[float] = None,
               rerank_every_steps: Optional[int] = None,
               **core_kw) -> LatencyReport:
    """Convenience: fresh scheduler + simulate + report. Core behaviour
    comes from ``config`` or loose keywords (chunking, caching, reservation
    mode, deadlines, shedding); a fault-configured run's dropped requests
    are counted in the report, never silently lost (conservation is
    asserted)."""
    if config is None:
        config = ServingConfig.from_kwargs(rerank_interval=rerank_interval,
                                           rerank_every_steps=
                                           rerank_every_steps, **core_kw)
    elif (core_kw or rerank_interval is not None
          or rerank_every_steps is not None):
        raise TypeError("pass either config=ServingConfig(...) or loose "
                        "core keywords, not both")
    # deep-ish copy so one policy run doesn't pollute another (deadlines and
    # class/SLO annotations carry over — they are workload, not run state)
    reqs = clone_requests(requests)
    sched = Scheduler(policy=policy, max_batch=max_batch,
                      continuous=continuous,
                      starvation_threshold=starvation_threshold,
                      preemption=preemption, max_preemptions=max_preemptions)
    core = make_sim_core(sched, cost=cost, kv_blocks=kv_blocks, config=config)
    core.submit(reqs)
    finished = core.run()
    assert len(finished) + len(core.dropped) == len(requests), \
        (len(finished), len(core.dropped), len(requests))
    return report(policy.name, finished,
                  counters=RunCounters(
                      reranks=(sched.rerank_count if config.rerank_enabled
                               else None),
                      dropped=tuple(core.dropped) if core.dropped else None))
