"""Discrete-event continuous-batching simulator.

Replays the *same* ``repro.core.scheduler.Scheduler`` object the real engine
uses against a calibrated iteration-time model, so 2000-request bursts and
arrival-rate sweeps (paper §IV-D) run in milliseconds on CPU. Semantics match
vLLM-style iteration-level batching:

* each iteration, every running request decodes exactly one token;
* newly admitted requests first pay a prefill cost proportional to their
  prompt length (folded into the iteration in which they are admitted,
  like vLLM's mixed prefill/decode steps);
* iteration time = base + per-token-in-batch cost (+ prefill term), which is
  the standard two-parameter decode-latency model for batched LLM serving.

Default constants approximate a 7B-class model on an A100 (the paper's
testbed scale): 25 ms base, 0.15 ms per running request per step, 0.5 ms per
prefill token. Absolute values shift all policies equally; the *relative*
policy gaps the paper reports are driven by queueing, not by the constants.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.serving.metrics import LatencyReport, report


@dataclass(frozen=True)
class CostModel:
    iter_base_s: float = 0.025       # fixed per-iteration cost
    per_seq_s: float = 0.00015       # marginal cost per running sequence
    prefill_per_token_s: float = 0.0005

    def iteration_time(self, batch_size: int, prefill_tokens: int) -> float:
        return (self.iter_base_s + self.per_seq_s * batch_size
                + self.prefill_per_token_s * prefill_tokens)


def simulate(requests: Sequence[Request], scheduler: Scheduler, *,
             cost: CostModel = CostModel(), max_time: float = 1e7,
             ) -> List[Request]:
    """Run to completion; returns the finished requests (with timestamps)."""
    pending = sorted(requests, key=lambda r: r.arrival_time)
    finished: List[Request] = []
    now = 0.0
    i = 0
    n = len(pending)
    while (i < n or scheduler.has_work) and now < max_time:
        # deliver arrivals
        arrived = []
        while i < n and pending[i].arrival_time <= now:
            arrived.append(pending[i])
            i += 1
        if arrived:
            scheduler.add_requests(arrived)
        if not scheduler.running and not scheduler.waiting:
            if i < n:                      # idle: jump to next arrival
                now = pending[i].arrival_time
                continue
            break
        admitted = scheduler.schedule(now)
        # recompute preemption: a re-admitted request re-prefills its prompt
        # plus everything it had already generated (vLLM recompute semantics)
        prefill_tokens = sum(
            r.prompt_len + (r.tokens_done if r.preempt_count else 0)
            for r in admitted)
        dt = cost.iteration_time(len(scheduler.running), prefill_tokens)
        now += dt
        for r in scheduler.running:
            r.tokens_done += 1
            if r.first_token_time is None:
                r.first_token_time = now
        finished.extend(scheduler.retire_finished(now))
    finished.extend(scheduler.retire_finished(now))
    return finished


def run_policy(requests: Sequence[Request], policy, *, max_batch: int = 16,
               continuous: bool = True, cost: CostModel = CostModel(),
               starvation_threshold: float = 120.0) -> LatencyReport:
    """Convenience: fresh scheduler + simulate + report."""
    # deep-ish copy so one policy run doesn't pollute another
    reqs = [Request(r.req_id, r.prompt, r.arrival_time, r.prompt_len,
                    r.true_length) for r in requests]
    sched = Scheduler(policy=policy, max_batch=max_batch,
                      continuous=continuous,
                      starvation_threshold=starvation_threshold)
    finished = simulate(reqs, sched, cost=cost)
    assert len(finished) == len(requests), (len(finished), len(requests))
    return report(policy.name, finished)
