"""Multi-replica front-end: predictor-aware, cache-affinity request routing.

One engine replica saturates long before "heavy traffic from millions of
users" does; the production shape is N data-parallel replicas behind one
front-end. :class:`ReplicaRouter` is that front-end: it owns the global
arrival queue and dispatches each request to one of N per-replica
:class:`~repro.serving.core.ServingCore` loops (Real or Sim backends — the
router never looks past the core's probe API). Routing reuses the same PARS
signal the in-replica scheduler ranks by, one level up:

* ``round_robin`` — cycle replicas in index order (the baseline every other
  policy is judged against).
* ``least_kv_pressure`` — the replica with the lowest referenced fraction of
  its KV budget (``ServingCore.kv_pressure``; absolute ``kv_used_blocks``
  breaks ties, so unbounded sim allocators still rank by load), then the
  shallowest queue.
* ``predicted_shortest_queue`` — the replica with the least *predicted
  remaining work*: for every unfinished request a replica owns, prompt
  tokens still to prefill plus ``max(predicted_len(r) − tokens_done, 0)``
  predicted decode tokens (``ServingCore.predicted_remaining_tokens``).
  ``predicted_len`` defaults to the PARS score annotated on the request —
  the ELIS-style dispatch-by-predicted-remaining-work rule applied across
  replicas instead of within one queue.
* ``prefix_affinity`` — the replica whose allocator already holds the
  longest *committed* chain-hash prefix of the request's prompt
  (``ServingCore.prefix_affinity_blocks``), so shared system prompts keep
  hitting the same replica's prefix cache instead of re-prefilling N times;
  replicas tie at zero affinity fall back to the ``least_kv_pressure``
  ordering. This is cross-replica cache *sharing* done as cache-aware
  routing — no KV bytes ever migrate between replicas.

Every choice is deterministic: metric policies take the per-replica argmin
of an explicit key tuple (lists indexed in replica order — no set/dict
iteration anywhere), and exact ties are broken by a ``random.Random(seed)``
owned by the router, so a fixed trace + fixed policy reproduces the same
assignment sequence run over run.

**Event order across replicas.** Each replica keeps its own clock (virtual
or wall). The router advances whichever replica has the earliest
``next_event_time()`` one :meth:`~repro.serving.core.ServingCore.tick` at a
time, and routes a pending arrival only once every replica's next event is
at-or-past its arrival time — the discrete-event guarantee that routing
probes observe replica state *as of the arrival*, not as of whenever the
trace was submitted. With one replica this reduces exactly to the core's
own ``run()`` loop (the N=1 parity tests assert bit-identical outputs and
equal metrics against a bare ``ServingCore``).

**Admission stays the replica's.** Routing hands a request to a replica's
pending queue; actually entering that replica's running batch still goes
through its scheduler's ``admit_hook`` KV gate. The router composes a
per-replica gate onto that same hook (``Scheduler.add_admit_gate``) to
count admission attempts — the congestion signal reported per replica —
rather than inventing a parallel admission mechanism.
"""
from __future__ import annotations

import random
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler.request import Request
from repro.serving.core import ServingCore
from repro.serving.metrics import RouterReport, router_report

ROUTING_POLICIES = ("round_robin", "least_kv_pressure",
                    "predicted_shortest_queue", "prefix_affinity")


def score_predicted_len(req: Request) -> float:
    """Default predicted output length: the PARS score the scheduling policy
    annotated at arrival, clipped at 0 (scores are relative ranks, so an
    unannotated request predicts zero remaining decode tokens and routes by
    prefill work + queue size alone). Only a fallback: when iterative
    re-ranking is on, ``ServingCore.predicted_remaining_tokens`` reads the
    refreshed ``Request.remaining_est`` instead of calling this."""
    return max(req.score, 0.0)


class ReplicaRouter:
    """Front-end dispatcher over N independent ``ServingCore`` replicas.

    ``replicas`` — already-constructed cores (own scheduler, allocator,
    backend, clock each; nothing is shared between them).
    ``policy`` — one of :data:`ROUTING_POLICIES`.
    ``predicted_len`` — request → predicted output length, used by
    ``predicted_shortest_queue`` (default: the request's PARS ``score``).
    ``seed`` — seeds the tie-break RNG, making exact-tie choices
    reproducible run over run.
    """

    def __init__(self, replicas: Sequence[ServingCore], *,
                 policy: str = "round_robin",
                 predicted_len: Optional[Callable[[Request], float]] = None,
                 seed: int = 0) -> None:
        if policy not in ROUTING_POLICIES:
            raise ValueError(f"policy must be one of {ROUTING_POLICIES}, "
                             f"got {policy!r}")
        if not replicas:
            raise ValueError("need at least one replica")
        self.replicas: List[ServingCore] = list(replicas)
        self.policy = policy
        self.predicted_len = predicted_len or score_predicted_len
        self._rng = random.Random(seed)
        self._pending: Deque[Request] = deque()
        self._rr_next = 0
        # req_id -> replica index, and the dispatch-ordered log the
        # determinism tests compare run over run
        self.assignments: Dict[int, int] = {}
        self.assignment_log: List[Tuple[int, int]] = []
        self.admit_attempts: List[int] = [0] * len(self.replicas)
        for i, core in enumerate(self.replicas):
            core.scheduler.add_admit_gate(self._admit_gate(i))

    def _admit_gate(self, idx: int) -> Callable[[Request], bool]:
        """Observer gate composed onto replica ``idx``'s admit_hook: counts
        every admission attempt (deferral pressure shows up as attempts ≫
        served requests) without ever vetoing one."""
        def gate(_req: Request) -> bool:
            self.admit_attempts[idx] += 1
            return True
        return gate

    # --------------------------------------------------------------- routing
    def submit(self, requests: Sequence[Request]) -> None:
        """Queue arrivals on the global front-end queue (merged by arrival
        time, same convention as ``ServingCore.submit``)."""
        self._pending = deque(sorted([*self._pending, *requests],
                                     key=lambda r: r.arrival_time))

    def _keys(self, req: Request) -> List[Tuple]:
        """Per-replica routing key for the configured policy (lower =
        better), indexed in replica order."""
        if self.policy == "least_kv_pressure":
            return [(c.kv_pressure(), c.kv_used_blocks(), c.queue_depth())
                    for c in self.replicas]
        if self.policy == "predicted_shortest_queue":
            return [(c.predicted_remaining_tokens(self.predicted_len),
                     c.queue_depth()) for c in self.replicas]
        if self.policy == "prefix_affinity":
            # longest committed prefix wins; zero-affinity replicas compare
            # by exactly the least_kv_pressure ordering (the fallback)
            return [(-c.prefix_affinity_blocks(req), c.kv_pressure(),
                     c.kv_used_blocks(), c.queue_depth())
                    for c in self.replicas]
        raise AssertionError(self.policy)

    def choose(self, req: Request) -> int:
        """Pick the replica for one request. ``round_robin`` cycles; metric
        policies take the argmin of :meth:`_keys`, exact ties broken by the
        seeded RNG (never by iteration order of anything unordered)."""
        if self.policy == "round_robin":
            idx = self._rr_next
            self._rr_next = (self._rr_next + 1) % len(self.replicas)
            return idx
        keys = self._keys(req)
        best = min(keys)
        tied = [i for i, k in enumerate(keys) if k == best]
        return tied[0] if len(tied) == 1 else self._rng.choice(tied)

    def dispatch(self, req: Request) -> int:
        """Route one request now: record the assignment and hand it to the
        chosen replica's pending queue (its own arrival/admission machinery
        takes over from there)."""
        idx = self.choose(req)
        self.assignments[req.req_id] = idx
        self.assignment_log.append((req.req_id, idx))
        self.replicas[idx].submit([req])
        return idx

    # ------------------------------------------------------------ event loop
    def _next_replica(self) -> Optional[int]:
        """The replica to advance next: earliest ``next_event_time``, ties to
        the lowest index (replica-list order — deterministic). ``None`` when
        every replica is drained."""
        best, best_t = None, float("inf")
        for i, core in enumerate(self.replicas):
            t = core.next_event_time()
            if t < best_t:
                best, best_t = i, t
        return best

    def step(self) -> bool:
        """One global event: route the next due arrival, or advance the
        earliest replica one serving cycle. Returns False when fully
        drained. An arrival is routed only once no replica's next event
        precedes it, so routing probes see replica state as of the arrival
        time (the discrete-event analogue of routing at arrival)."""
        idx = self._next_replica()
        t_core = (self.replicas[idx].next_event_time()
                  if idx is not None else float("inf"))
        if self._pending and self._pending[0].arrival_time <= t_core:
            self.dispatch(self._pending.popleft())
            return True
        if idx is None:
            return False
        self.replicas[idx].tick()
        return True

    def run(self, *, max_steps: Optional[int] = None) -> List[Request]:
        """Drive routing + every replica to completion; returns all finished
        requests (sorted by req_id). ``max_steps`` bounds the global event
        count (property tests interleave bounded runs with invariant
        checks)."""
        steps = 0
        while self.step():
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.finished

    # --------------------------------------------------------------- results
    @property
    def finished(self) -> List[Request]:
        out = [r for core in self.replicas for r in core.finished]
        out.sort(key=lambda r: r.req_id)
        return out

    def report(self, label: Optional[str] = None) -> RouterReport:
        """Aggregate + per-replica metrics for everything finished so far
        (NaN-safe when some replica served nothing)."""
        reranked = any(c._rerank_enabled for c in self.replicas)
        return router_report(label or self.policy,
                             [core.finished for core in self.replicas],
                             admit_attempts=self.admit_attempts,
                             reranks=(sum(c.rerank_count
                                          for c in self.replicas)
                                      if reranked else None))
