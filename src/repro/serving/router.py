"""Multi-replica front-end: predictor-aware, cache-affinity request routing.

One engine replica saturates long before "heavy traffic from millions of
users" does; the production shape is N data-parallel replicas behind one
front-end. :class:`ReplicaRouter` is that front-end: it owns the global
arrival queue and dispatches each request to one of N per-replica
:class:`~repro.serving.core.ServingCore` loops (Real or Sim backends — the
router never looks past the core's probe API). Routing reuses the same PARS
signal the in-replica scheduler ranks by, one level up:

* ``round_robin`` — cycle replicas in index order (the baseline every other
  policy is judged against).
* ``least_kv_pressure`` — the replica with the lowest referenced fraction of
  its KV budget (``ServingCore.kv_pressure``; absolute ``kv_used_blocks``
  breaks ties, so unbounded sim allocators still rank by load), then the
  shallowest queue.
* ``predicted_shortest_queue`` — the replica with the least *predicted
  remaining work*: for every unfinished request a replica owns, prompt
  tokens still to prefill plus ``max(predicted_len(r) − tokens_done, 0)``
  predicted decode tokens (``ServingCore.predicted_remaining_tokens``).
  ``predicted_len`` defaults to the PARS score annotated on the request —
  the ELIS-style dispatch-by-predicted-remaining-work rule applied across
  replicas instead of within one queue.
* ``prefix_affinity`` — the replica whose allocator already holds the
  longest *committed* chain-hash prefix of the request's prompt
  (``ServingCore.prefix_affinity_blocks``), so shared system prompts keep
  hitting the same replica's prefix cache instead of re-prefilling N times;
  replicas tie at zero affinity fall back to the ``least_kv_pressure``
  ordering. This is cross-replica cache *sharing* done as cache-aware
  routing — no KV bytes ever migrate between replicas.

Every choice is deterministic: metric policies take the per-replica argmin
of an explicit key tuple (lists indexed in replica order — no set/dict
iteration anywhere), and exact ties are broken by a ``random.Random(seed)``
owned by the router, so a fixed trace + fixed policy reproduces the same
assignment sequence run over run.

**Event order across replicas.** Each replica keeps its own clock (virtual
or wall). The router advances whichever replica has the earliest
``next_event_time()`` one :meth:`~repro.serving.core.ServingCore.tick` at a
time, and routes a pending arrival only once every replica's next event is
at-or-past its arrival time — the discrete-event guarantee that routing
probes observe replica state *as of the arrival*, not as of whenever the
trace was submitted. With one replica this reduces exactly to the core's
own ``run()`` loop (the N=1 parity tests assert bit-identical outputs and
equal metrics against a bare ``ServingCore``).

**Admission stays the replica's.** Routing hands a request to a replica's
pending queue; actually entering that replica's running batch still goes
through its scheduler's ``admit_hook`` KV gate. The router composes a
per-replica gate onto that same hook (``Scheduler.add_admit_gate``) to
count admission attempts — the congestion signal reported per replica —
rather than inventing a parallel admission mechanism.

**Replica failover.** Every probe and tick is a liveness check: a replica
whose core raises :class:`~repro.serving.faults.ReplicaCrashed` is marked
unhealthy and leaves the routing pool immediately. Its in-flight requests
are *lost KV* — there is nothing to drain — so the router extracts them
(``ServingCore.crash``), strips their assignment, and re-dispatches each to
a healthy replica with recompute-from-prompt semantics, after an
exponential-backoff delay (``failover_backoff_s · 2^(failovers−1)``) and
under a bounded retry budget (``max_failovers``; a request that keeps
landing on dying replicas exits terminally ``FAILED`` instead of looping
forever). Restarts are scheduled through :meth:`schedule_restart` (a fault
schedule calls it from ``on_replica_down``): at the given global event
count the replica rejoins the pool *cold* — empty queues, empty prefix
cache — and routing sees it as a fresh replica. Request conservation is a
router-level invariant: every submitted request is at all times in exactly
one of pending / retry-backoff / exactly-one-replica / finished / dropped.

**Routing-aware starvation bound.** ``affinity_escape_after=K`` releases a
request that the KV gate has rejected ≥ K times on the replica it was
routed to (typically its warm prefix-affinity replica): the router pulls it
back and re-dispatches it to a *different* replica, trading the warm cache
for actually running — the cross-replica analogue of the scheduler's
boost-after-starvation rule.
"""
from __future__ import annotations

import random
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler.request import Request, RequestState
from repro.serving.core import ServingCore
from repro.serving.faults import ReplicaCrashed
from repro.serving.metrics import RouterReport, RunCounters, router_report

ROUTING_POLICIES = ("round_robin", "least_kv_pressure",
                    "predicted_shortest_queue", "prefix_affinity")


def score_predicted_len(req: Request) -> float:
    """Default predicted output length: the PARS score the scheduling policy
    annotated at arrival, clipped at 0 (scores are relative ranks, so an
    unannotated request predicts zero remaining decode tokens and routes by
    prefill work + queue size alone). Only a fallback: when iterative
    re-ranking is on, ``ServingCore.predicted_remaining_tokens`` reads the
    refreshed ``Request.remaining_est`` instead of calling this."""
    return max(req.score, 0.0)


class ReplicaRouter:
    """Front-end dispatcher over N independent ``ServingCore`` replicas.

    ``replicas`` — already-constructed cores (own scheduler, allocator,
    backend, clock each; nothing is shared between them).
    ``policy`` — one of :data:`ROUTING_POLICIES`.
    ``predicted_len`` — request → predicted output length, used by
    ``predicted_shortest_queue`` (default: the request's PARS ``score``).
    ``seed`` — seeds the tie-break RNG, making exact-tie choices
    reproducible run over run.
    ``max_failovers`` — crash-failover retries per request before it exits
    terminally ``FAILED``.
    ``failover_backoff_s`` — base of the exponential re-dispatch backoff
    after a crash (``backoff · 2^(failovers−1)``).
    ``affinity_escape_after`` — release a request KV-gate-rejected this many
    times on its routed replica to route elsewhere (``None`` = never).
    """

    def __init__(self, replicas: Sequence[ServingCore], *,
                 policy: str = "round_robin",
                 predicted_len: Optional[Callable[[Request], float]] = None,
                 seed: int = 0,
                 max_failovers: int = 3,
                 failover_backoff_s: float = 0.5,
                 affinity_escape_after: Optional[int] = None) -> None:
        if policy not in ROUTING_POLICIES:
            raise ValueError(f"policy must be one of {ROUTING_POLICIES}, "
                             f"got {policy!r}")
        if not replicas:
            raise ValueError("need at least one replica")
        if affinity_escape_after is not None and affinity_escape_after < 1:
            raise ValueError("affinity_escape_after must be >= 1 or None")
        self.replicas: List[ServingCore] = list(replicas)
        self.policy = policy
        self.predicted_len = predicted_len or score_predicted_len
        self._rng = random.Random(seed)
        self._pending: Deque[Request] = deque()
        self._rr_next = 0
        # req_id -> replica index, and the dispatch-ordered log the
        # determinism tests compare run over run
        self.assignments: Dict[int, int] = {}
        self.assignment_log: List[Tuple[int, int]] = []
        self.admit_attempts: List[int] = [0] * len(self.replicas)
        # ----------------------------------------------------------- failover
        self.max_failovers = max_failovers
        self.failover_backoff_s = failover_backoff_s
        self.affinity_escape_after = affinity_escape_after
        self.healthy: List[bool] = [True] * len(self.replicas)
        self.crash_count: List[int] = [0] * len(self.replicas)
        self.restarts: List[int] = [0] * len(self.replicas)
        self.redispatches = 0                 # failover + escape re-routes
        self._routed_ids: set = set()         # ever-dispatched req_ids
        self.dropped: List[Request] = []      # FAILED (budget exhausted)
        # requests waiting out their failover backoff, sorted by
        # (route_after, req_id)
        self._retry: List[Request] = []
        # replica -> global event count at which to restart it
        self._restart_at: Dict[int, int] = {}
        # req_id -> gate_rejections at its last dispatch (the affinity
        # escape counts rejections *since routing*, not lifetime)
        self._rejections_at_route: Dict[int, int] = {}
        self.event_count = 0
        # per-event fault injection point (repro.serving.faults attaches
        # here); ``None`` on healthy runs
        self.fault_hook: Optional[Callable[["ReplicaRouter"], None]] = None
        # fired after a replica is marked down (fault schedules hook restart
        # planning here); signature ``(router, replica_idx)``
        self.on_replica_down: Optional[
            Callable[["ReplicaRouter", int], None]] = None
        for i, core in enumerate(self.replicas):
            core.scheduler.add_admit_gate(self._admit_gate(i))

    def _admit_gate(self, idx: int) -> Callable[[Request], bool]:
        """Observer gate composed onto replica ``idx``'s admit_hook: counts
        every admission attempt (deferral pressure shows up as attempts ≫
        served requests) without ever vetoing one."""
        def gate(_req: Request) -> bool:
            self.admit_attempts[idx] += 1
            return True
        return gate

    # --------------------------------------------------------------- routing
    def submit(self, requests: Sequence[Request]) -> None:
        """Queue arrivals on the global front-end queue (merged by arrival
        time, same convention as ``ServingCore.submit``)."""
        self._pending = deque(sorted([*self._pending, *requests],
                                     key=lambda r: r.arrival_time))

    def _key_for(self, idx: int, req: Request) -> Tuple:
        """Replica ``idx``'s routing key for the configured policy (lower =
        better). May raise ``ReplicaCrashed`` — probe failure is how a
        not-yet-detected dead replica is discovered mid-choice."""
        c = self.replicas[idx]
        if self.policy == "least_kv_pressure":
            return (c.kv_pressure(), c.kv_used_blocks(), c.queue_depth())
        if self.policy == "predicted_shortest_queue":
            return (c.predicted_remaining_tokens(self.predicted_len),
                    c.queue_depth())
        if self.policy == "prefix_affinity":
            # longest committed prefix wins; zero-affinity replicas compare
            # by exactly the least_kv_pressure ordering (the fallback)
            return (-c.prefix_affinity_blocks(req), c.kv_pressure(),
                    c.kv_used_blocks(), c.queue_depth())
        raise AssertionError(self.policy)

    def _keys(self, req: Request,
              exclude: frozenset = frozenset()) -> List[Optional[Tuple]]:
        """Per-replica routing keys, indexed in replica order; ``None`` for
        replicas out of the running (unhealthy, excluded, or found dead by
        the probe itself — those are failed over on the spot)."""
        keys: List[Optional[Tuple]] = []
        for i in range(len(self.replicas)):
            if not self.healthy[i] or i in exclude:
                keys.append(None)
                continue
            try:
                keys.append(self._key_for(i, req))
            except ReplicaCrashed:
                self._fail_replica(i)
                keys.append(None)
        return keys

    def choose(self, req: Request,
               exclude: frozenset = frozenset()) -> Optional[int]:
        """Pick the replica for one request. ``round_robin`` cycles (skipping
        unhealthy replicas); metric policies take the argmin of
        :meth:`_keys`, exact ties broken by the seeded RNG (never by
        iteration order of anything unordered). ``None`` when no healthy
        non-excluded replica exists."""
        if self.policy == "round_robin":
            for _ in range(len(self.replicas)):
                idx = self._rr_next
                self._rr_next = (self._rr_next + 1) % len(self.replicas)
                if self.healthy[idx] and idx not in exclude:
                    return idx
            return None
        keys = self._keys(req, exclude)
        live = [k for k in keys if k is not None]
        if not live:
            return None
        best = min(live)
        tied = [i for i, k in enumerate(keys) if k == best]
        return tied[0] if len(tied) == 1 else self._rng.choice(tied)

    def dispatch(self, req: Request,
                 *, exclude: frozenset = frozenset()) -> Optional[int]:
        """Route one request now: record the assignment and hand it to the
        chosen replica's pending queue (its own arrival/admission machinery
        takes over from there). A request seen before (failover retry or
        affinity escape — including one that bounced through the front-end
        queue because no replica was healthy when its retry came due)
        counts as a re-route, so ``assignment_log`` uniqueness accounting
        stays exact: duplicates in the log == ``redispatches``. Returns the
        chosen replica, or ``None`` (request requeued at the front-end)
        when no healthy replica is available."""
        idx = self.choose(req, exclude)
        while idx is not None:
            try:
                self.replicas[idx].submit([req])
                break
            except ReplicaCrashed:
                # an undiscovered dead replica (round_robin never probes,
                # so the hand-off itself is the liveness check here): fail
                # it over and re-choose for this request on the spot
                self._fail_replica(idx)
                idx = self.choose(req, exclude)
        if idx is None:
            self._pending.appendleft(req)
            return None
        self.assignments[req.req_id] = idx
        self.assignment_log.append((req.req_id, idx))
        self._rejections_at_route[req.req_id] = req.gate_rejections
        if req.req_id in self._routed_ids:
            self.redispatches += 1
        else:
            self._routed_ids.add(req.req_id)
        return idx

    # -------------------------------------------------------------- failover
    def _fail_replica(self, idx: int) -> None:
        """Mark replica ``idx`` dead and fail its requests over. Idempotent
        (probe failure and tick failure can both report the same crash).

        The crashed core's requests lost their KV; each one is stripped of
        its assignment and either queued for re-dispatch after exponential
        backoff, or — past ``max_failovers`` — exits terminally ``FAILED``.
        """
        if not self.healthy[idx]:
            return
        self.healthy[idx] = False
        self.crash_count[idx] += 1
        core = self.replicas[idx]
        now = core.clock.now()
        for r in core.crash():
            self.assignments.pop(r.req_id, None)
            self._rejections_at_route.pop(r.req_id, None)
            r.failovers = (r.failovers or 0) + 1
            if r.failovers > self.max_failovers:
                r.state = RequestState.FAILED
                r.drop_reason = "failover-budget"
                r.finish_time = now
                self.dropped.append(r)
            else:
                r.state = RequestState.WAITING
                r.route_after = (now + self.failover_backoff_s
                                 * 2 ** (r.failovers - 1))
                self._retry.append(r)
        self._retry.sort(key=lambda r: (r.route_after, r.req_id))
        if self.on_replica_down is not None:
            self.on_replica_down(self, idx)

    def schedule_restart(self, idx: int, at_event: int) -> None:
        """Restart replica ``idx`` once the global event count reaches
        ``at_event`` (fault schedules call this from ``on_replica_down``).
        The router performs due restarts itself at the top of each event —
        and knows, when every replica is down, whether waiting for one is
        worthwhile."""
        self._restart_at[idx] = at_event

    def restart_replica(self, idx: int) -> None:
        """Rejoin a crashed replica cold: it re-enters the routing pool with
        empty queues and an empty prefix cache, like a fresh replica."""
        if self.healthy[idx]:
            return
        self.replicas[idx].restart()
        self.healthy[idx] = True
        self.restarts[idx] += 1

    def _fire_restarts(self) -> None:
        for idx in [i for i, at in self._restart_at.items()
                    if self.event_count >= at]:
            del self._restart_at[idx]
            self.restart_replica(idx)

    def _reclaim_starved(self) -> None:
        """Routing-aware starvation bound: a waiting request whose replica's
        KV gate has rejected it ``affinity_escape_after`` times *since it
        was routed there* is pulled back and re-dispatched to a different
        replica — trading its warm prefix for actually running. Needs a
        second healthy replica to escape to."""
        if sum(self.healthy) < 2:
            return
        k = self.affinity_escape_after
        for i, core in enumerate(self.replicas):
            if not self.healthy[i]:
                continue
            stuck = [r for r in core.scheduler.waiting
                     if r.gate_rejections
                     - self._rejections_at_route.get(r.req_id, 0) >= k]
            if not stuck:
                continue
            ids = {id(r) for r in stuck}
            core.scheduler.waiting = [w for w in core.scheduler.waiting
                                      if id(w) not in ids]
            for r in stuck:
                core._hash_memo.pop(r.req_id, None)
                self.assignments.pop(r.req_id, None)
                r.prefilled_tokens = 0
                r.prefill_target = None
                self.dispatch(r, exclude=frozenset((i,)))

    # ------------------------------------------------------------ event loop
    def _earliest(self) -> Tuple[Optional[int], float]:
        """The healthy replica to advance next (earliest ``next_event_time``,
        ties to the lowest index) and its event time. Replicas found dead by
        the probe are failed over on the spot. ``(None, inf)`` when no
        healthy replica has work."""
        best, best_t = None, float("inf")
        for i, core in enumerate(self.replicas):
            if not self.healthy[i]:
                continue
            try:
                t = core.next_event_time()
            except ReplicaCrashed:
                self._fail_replica(i)
                continue
            if t < best_t:
                best, best_t = i, t
        return best, best_t

    def _next_replica(self) -> Optional[int]:
        """The replica to advance next; ``None`` when every healthy replica
        is drained."""
        return self._earliest()[0]

    def step(self) -> bool:
        """One global event: fire due restarts, reclaim affinity-starved
        requests, then route the next due arrival or failover retry, or
        advance the earliest healthy replica one serving cycle. Returns
        False when fully drained (or stalled with every replica down and no
        restart scheduled). An arrival is routed only once no healthy
        replica's next event precedes it, so routing probes see replica
        state as of the arrival time; failover retries wait out their
        backoff (``route_after``) under the same rule."""
        self.event_count += 1
        if self.fault_hook is not None:
            self.fault_hook(self)
        if self._restart_at:
            self._fire_restarts()
        if self.affinity_escape_after is not None:
            self._reclaim_starved()
        idx, t_core = self._earliest()
        arr_t = (self._pending[0].arrival_time if self._pending
                 else float("inf"))
        retry_t = self._retry[0].route_after if self._retry else float("inf")
        if ((self._pending or self._retry)
                and min(arr_t, retry_t) <= t_core and any(self.healthy)):
            req = (self._retry.pop(0) if retry_t <= arr_t
                   else self._pending.popleft())
            self.dispatch(req)
            return True
        if idx is None:
            # every healthy replica is drained. If work is stranded behind a
            # scheduled restart, idle this event (the event count is what
            # advances restart deadlines); with no restart coming, stop.
            return bool(self._restart_at
                        and (self._pending or self._retry))
        try:
            self.replicas[idx].tick()
        except ReplicaCrashed:
            self._fail_replica(idx)
        return True

    def run(self, *, max_steps: Optional[int] = None) -> List[Request]:
        """Drive routing + every replica to completion; returns all finished
        requests (sorted by req_id). ``max_steps`` bounds the global event
        count (property tests interleave bounded runs with invariant
        checks)."""
        steps = 0
        while self.step():
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.finished

    # --------------------------------------------------------------- results
    @property
    def finished(self) -> List[Request]:
        out = [r for core in self.replicas for r in core.finished]
        out.sort(key=lambda r: r.req_id)
        return out

    @property
    def all_dropped(self) -> List[Request]:
        """Every terminally dropped request: per-replica drops (cancelled /
        shed / rejected) plus router-level failover-budget failures."""
        out = [r for core in self.replicas for r in core.dropped]
        out.extend(self.dropped)
        out.sort(key=lambda r: r.req_id)
        return out

    def report(self, label: Optional[str] = None) -> RouterReport:
        """Aggregate + per-replica metrics for everything finished so far
        (NaN-safe when some replica served nothing). Counter collection
        lives in :meth:`RunCounters.from_router`, the one place that knows
        which router layers were active."""
        return router_report(label or self.policy,
                             [core.finished for core in self.replicas],
                             counters=RunCounters.from_router(self))
