"""Deterministic fault injection for the serving stack.

Production prediction-based schedulers treat component failure as a
first-class input — ELIS's iterative scheduler tolerates stale or broken
estimates, and proxy-model serving degrades to FCFS when the proxy is
unavailable. This module makes failure *injectable, seeded, and observable*
for this repo's serving stack: a :class:`FaultSchedule` describes exactly
which faults fire when, and attaches itself to the existing extension
points — the serving core's per-step ``fault_hook``, the router's per-event
``fault_hook`` / ``on_replica_down`` callbacks, and a wrapped scorer
callable — so the hot path carries **no testing branches**: a run with no
schedule attached executes byte-for-byte the same instructions as before
this module existed.

Fault kinds (all deterministic under a fixed schedule):

* **Replica crash / restart** (:class:`ReplicaCrash`) — the replica's
  serving core raises :class:`ReplicaCrashed` at its own step ``at_step``;
  the router detects the dead replica (tick or probe failure), marks it
  unhealthy, and fails its in-flight requests over to healthy replicas
  (their KV is lost — recompute-from-prompt, bounded retries, exponential
  backoff). ``down_events`` router events later the replica restarts and
  rejoins the routing pool cold.
* **Scorer faults** (:class:`ScorerOutage`) — the wrapped scorer raises
  :class:`ScorerError` (or :class:`ScorerTimeout`) on scheduled batched
  dispatches; the policy's failure budget then degrades ranking to FCFS
  until the scorer heals (see ``Policy`` in
  :mod:`repro.core.scheduler.policies`).
* **KV grow-failure storms** (:class:`GrowStorm`) — ``allocator.grow``
  returns ``False`` for every call inside a step window, exercising the
  core's grow-denial preemption / self-deferral ladder under pressure that
  real fragmentation or concurrent growth would cause.
* **Clock-skewed arrivals** (:meth:`FaultSchedule.skew_arrivals`) — seeded
  bounded jitter on arrival timestamps, modelling skewed front-end clocks.

Use :meth:`FaultSchedule.chaos` to generate a randomized-but-seeded
schedule, or construct the event tuples explicitly for pinpoint tests.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


class ReplicaCrashed(RuntimeError):
    """A serving-core replica died: raised by the fault hook at the
    scheduled step, and by every probe/tick on a core whose ``inject_crash``
    flag is set — the router treats any of these as replica death."""


class ScorerError(RuntimeError):
    """Injected scorer dispatch failure (the predictor process died,
    returned garbage, …)."""


class ScorerTimeout(ScorerError):
    """Injected scorer dispatch timeout (the predictor stalled past the
    policy's deadline). A subclass of :class:`ScorerError`: both count
    against the same failure budget."""


@dataclass(frozen=True)
class ReplicaCrash:
    """Replica ``replica`` crashes when its core reaches step ``at_step``
    (1-based, compared against ``ServingCore.step_count``); ``down_events``
    router events later it restarts cold. ``None`` = never restarts."""
    replica: int
    at_step: int
    down_events: Optional[int] = None


@dataclass(frozen=True)
class ScorerOutage:
    """Batched scorer dispatches ``[first_call, first_call + n_calls)``
    (0-based call index on the *wrapped* scorer) fail with
    :class:`ScorerError`, or :class:`ScorerTimeout` when ``kind`` is
    ``"timeout"``."""
    first_call: int
    n_calls: int
    kind: str = "error"


@dataclass(frozen=True)
class GrowStorm:
    """Every ``allocator.grow`` call on ``replica`` while its core's
    ``step_count`` is in ``[start_step, end_step)`` is denied."""
    replica: int
    start_step: int
    end_step: int


@dataclass(frozen=True)
class ArrivalSkew:
    """Uniform arrival-time jitter in ``[-max_abs_s, +max_abs_s]`` seconds,
    clipped at 0 (no arrivals before the trace origin)."""
    max_abs_s: float


@dataclass
class FaultSchedule:
    """One deterministic plan of injected faults, attached via hooks.

    The schedule is pure data plus attachment methods; it owns injection
    *counters* (``injected_*``) so a chaos run can assert every scheduled
    fault actually fired. Counters are cumulative across attachments —
    call :meth:`reset_counters` between runs that reuse one schedule.
    """
    crashes: Tuple[ReplicaCrash, ...] = ()
    scorer_outages: Tuple[ScorerOutage, ...] = ()
    grow_storms: Tuple[GrowStorm, ...] = ()
    arrival_skew: Optional[ArrivalSkew] = None
    seed: int = 0
    injected_crashes: int = field(default=0, init=False)
    injected_scorer_faults: int = field(default=0, init=False)
    injected_grow_denials: int = field(default=0, init=False)

    # ------------------------------------------------------------- factories
    @classmethod
    def chaos(cls, seed: int, *, n_replicas: int, horizon_steps: int = 200,
              n_crashes: int = 2, restart_events: int = 40,
              n_scorer_outages: int = 1, outage_calls: int = 4,
              n_grow_storms: int = 1, storm_steps: int = 5,
              arrival_skew_s: float = 0.0) -> "FaultSchedule":
        """A randomized-but-seeded schedule: the same ``(seed, kwargs)``
        always produces the same fault plan, so a chaos run is exactly
        reproducible."""
        rng = random.Random(seed)
        crashes = tuple(
            ReplicaCrash(replica=rng.randrange(n_replicas),
                         at_step=rng.randint(2, max(horizon_steps, 3)),
                         down_events=restart_events)
            for _ in range(n_crashes))
        outages = tuple(
            ScorerOutage(first_call=rng.randint(1, 20),
                         n_calls=outage_calls,
                         kind=rng.choice(("error", "timeout")))
            for _ in range(n_scorer_outages))
        storms = []
        for _ in range(n_grow_storms):
            start = rng.randint(2, max(horizon_steps, 3))
            storms.append(GrowStorm(replica=rng.randrange(n_replicas),
                                    start_step=start,
                                    end_step=start + storm_steps))
        skew = ArrivalSkew(arrival_skew_s) if arrival_skew_s > 0 else None
        return cls(crashes=crashes, scorer_outages=outages,
                   grow_storms=tuple(storms), arrival_skew=skew, seed=seed)

    def reset_counters(self) -> None:
        self.injected_crashes = 0
        self.injected_scorer_faults = 0
        self.injected_grow_denials = 0

    # ------------------------------------------------------------ attachment
    def wrap_scorer(self, scorer):
        """A scorer that fails on the scheduled batched-dispatch indices and
        delegates otherwise. Each wrap owns its own call counter, so one
        schedule can wrap many policies (e.g. one per replica) and each
        counts its own dispatches."""
        outages = self.scorer_outages
        state = {"calls": 0}

        def faulty(prompts):
            i = state["calls"]
            state["calls"] += 1
            for o in outages:
                if o.first_call <= i < o.first_call + o.n_calls:
                    self.injected_scorer_faults += 1
                    exc = ScorerTimeout if o.kind == "timeout" else ScorerError
                    raise exc(f"injected scorer {o.kind} on dispatch {i}")
            return scorer(prompts)
        return faulty

    def attach_core(self, core, replica: int = 0) -> None:
        """Install this schedule's per-step faults on one serving core:
        a ``fault_hook`` that raises :class:`ReplicaCrashed` at the
        scheduled crash steps, and a ``grow`` wrapper that denies every
        allocation-growth call inside a storm window. Cores with no
        scheduled faults for ``replica`` are left untouched (their hot path
        stays hook-free)."""
        crash_steps = {c.at_step for c in self.crashes if c.replica == replica}
        storms = [s for s in self.grow_storms if s.replica == replica]
        if crash_steps:
            def hook(c, _now, _steps=crash_steps):
                if c.step_count in _steps:
                    self.injected_crashes += 1
                    raise ReplicaCrashed(
                        f"injected crash at step {c.step_count}")
            core.fault_hook = hook
        if storms:
            orig_grow = core.allocator.grow

            def stormy_grow(req_id, n, _core=core, _storms=storms,
                            _orig=orig_grow):
                if any(s.start_step <= _core.step_count < s.end_step
                       for s in _storms):
                    self.injected_grow_denials += 1
                    return False
                return _orig(req_id, n)
            core.allocator.grow = stormy_grow

    def attach_router(self, router) -> None:
        """Wire the whole schedule onto a multi-replica router: per-replica
        core faults, plus restart scheduling — when the router reports a
        replica down (``on_replica_down``), the matching crash's
        ``down_events`` books a restart with the router itself
        (``schedule_restart``), so the router knows a rejoin is coming and
        keeps draining stranded work instead of stalling while every
        replica is down."""
        for i, core in enumerate(router.replicas):
            self.attach_core(core, replica=i)
        down_plan: Dict[int, List[Optional[int]]] = {}
        for c in self.crashes:
            down_plan.setdefault(c.replica, []).append(c.down_events)

        def on_down(rt, idx):
            plan = down_plan.get(idx)
            down = plan.pop(0) if plan else None
            if down is not None:
                rt.schedule_restart(idx, rt.event_count + down)
        router.on_replica_down = on_down

    # --------------------------------------------------------------- arrivals
    def skew_arrivals(self, requests: Sequence) -> None:
        """Apply seeded clock skew to a trace in place (bounded uniform
        jitter per request, clipped at 0), modelling skewed front-end
        clocks. Deterministic: jitter depends only on ``(seed, req_id)``."""
        if self.arrival_skew is None:
            return
        m = self.arrival_skew.max_abs_s
        for r in requests:
            u = random.Random(self.seed * 1_000_003 + r.req_id).uniform(-m, m)
            r.arrival_time = max(0.0, r.arrival_time + u)
