"""ServingConfig: the one frozen record of a serving core's behaviour knobs.

``ServingCore`` grew one keyword argument per feature PR — chunking, prefix
caching, reservation mode, four re-ranking knobs, deadline and shedding
thresholds — until constructing a core meant threading sixteen loose kwargs
through every helper (``make_sim_core``, ``simulate``, ``Engine``, the
benchmarks), with the validation rules duplicated wherever someone built one
by hand. :class:`ServingConfig` consolidates them:

* **frozen** — a config is a value. Two runs built from the same config are
  the same run; benchmarks put the config itself in their JSON output and a
  diff of configs is a diff of behaviours.
* **validated once** — every rule that used to live in
  ``ServingCore.__init__`` lives in :meth:`__post_init__`, so an invalid
  combination fails at config construction, before any scheduler or backend
  exists.
* **round-trippable** — :meth:`to_kwargs` / :meth:`from_kwargs` convert to
  and from the historical keyword form bit-exactly (pinned by tests), which
  is what the legacy-kwargs deprecation shim on ``ServingCore`` uses.

Construction objects (the scheduler, backend, allocator, clock) are *wiring*,
not configuration — they stay direct constructor arguments.

    core = ServingCore(scheduler, backend,
                       config=ServingConfig(prefill_chunk_tokens=256,
                                            prefix_caching=True))

The legacy form ``ServingCore(scheduler, backend, prefix_caching=True, ...)``
still works for one release and emits a :class:`DeprecationWarning`; the
blessed helpers (``make_sim_core`` / ``simulate`` / ``Engine``) translate
loose kwargs into a config internally without the warning.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

#: Reservation modes the admission gate understands (see ServingCore docs).
KV_RESERVATION_MODES = ("full", "incremental")


@dataclass(frozen=True)
class ServingConfig:
    """Behavioural configuration of one :class:`~repro.serving.core.ServingCore`.

    Every field defaults to the historical off/feature-disabled value, so
    ``ServingConfig()`` is exactly the pre-config core: unchunked prefill,
    no caching, full reservation, write-once ranks, no deadlines, no
    shedding.
    """

    # -- chunked prefill ----------------------------------------------------
    #: Per-step prompt-token budget for mixed prefill/decode steps; ``None``
    #: prefills each admitted request to completion in its admission step.
    prefill_chunk_tokens: Optional[int] = None
    #: Record a per-token timestamp on ``Request.token_times`` (enables
    #: gap-based ITL percentiles and per-request ITL SLO attainment).
    record_token_times: bool = False
    # -- prefix caching -----------------------------------------------------
    #: Share KV blocks between requests whose prompts share a leading run of
    #: whole blocks (refcounted, commit-gated — see kv_cache).
    prefix_caching: bool = False
    # -- KV reservation -----------------------------------------------------
    #: ``"full"`` reserves worst-case demand at admission; ``"incremental"``
    #: admits on prompt + one block and grows per decode step.
    kv_reservation: str = "full"
    # -- iterative re-ranking ----------------------------------------------
    #: Refresh priority keys to predicted *remaining* length every this many
    #: clock seconds (``None`` = no time cadence).
    rerank_interval: Optional[float] = None
    #: ... and/or every this many serving cycles (``None`` = no step cadence).
    rerank_every_steps: Optional[int] = None
    #: Lower bound on a refreshed remaining-length key.
    rerank_floor: float = 0.0
    #: Starvation bound: pin a request boosted after this many demotions.
    rerank_pin_after: int = 3
    # -- deadlines ----------------------------------------------------------
    #: Predicted seconds per output token; with it set, a waiting request
    #: whose predicted service time overruns its deadline is cancelled at
    #: admission instead of wasting prefill.
    deadline_time_per_token: Optional[float] = None
    # -- load shedding ------------------------------------------------------
    #: Queue-depth overload threshold (``None`` = queue depth never sheds).
    shed_queue_depth: Optional[int] = None
    #: KV-pressure overload threshold in [0, 1] (``None`` = never).
    shed_kv_pressure: Optional[float] = None
    #: Consecutive over-threshold steps before shedding activates.
    shed_sustain_steps: int = 3
    #: While shedding is active, refuse admission to work predicted longer
    #: than this many tokens (high-priority classes are exempt — see core).
    shed_predicted_tokens: Optional[float] = None

    def __post_init__(self) -> None:
        if (self.prefill_chunk_tokens is not None
                and self.prefill_chunk_tokens <= 0):
            raise ValueError("prefill_chunk_tokens must be positive or None")
        if self.kv_reservation not in KV_RESERVATION_MODES:
            raise ValueError(f"kv_reservation must be one of "
                             f"{KV_RESERVATION_MODES}, "
                             f"got {self.kv_reservation!r}")
        if self.rerank_interval is not None and self.rerank_interval <= 0:
            raise ValueError("rerank_interval must be positive or None")
        if (self.rerank_every_steps is not None
                and self.rerank_every_steps <= 0):
            raise ValueError("rerank_every_steps must be positive or None")
        if self.rerank_pin_after < 0:
            raise ValueError("rerank_pin_after must be >= 0")
        if (self.deadline_time_per_token is not None
                and self.deadline_time_per_token < 0):
            raise ValueError("deadline_time_per_token must be >= 0 or None")
        if self.shed_queue_depth is not None and self.shed_queue_depth < 0:
            raise ValueError("shed_queue_depth must be >= 0 or None")
        if (self.shed_kv_pressure is not None
                and not 0.0 <= self.shed_kv_pressure <= 1.0):
            raise ValueError("shed_kv_pressure must be in [0, 1] or None")
        if self.shed_sustain_steps < 1:
            raise ValueError("shed_sustain_steps must be >= 1")
        if (self.shed_predicted_tokens is not None
                and self.shed_predicted_tokens <= 0):
            raise ValueError("shed_predicted_tokens must be positive or None")

    # ------------------------------------------------------------- derived
    @property
    def rerank_enabled(self) -> bool:
        return (self.rerank_interval is not None
                or self.rerank_every_steps is not None)

    @property
    def shed_enabled(self) -> bool:
        return (self.shed_queue_depth is not None
                or self.shed_kv_pressure is not None)

    # ---------------------------------------------------------- conversion
    @classmethod
    def field_names(cls) -> tuple:
        return tuple(f.name for f in dataclasses.fields(cls))

    @classmethod
    def from_kwargs(cls, **kwargs) -> "ServingConfig":
        """Build from the historical loose-kwargs form; unknown names raise
        ``TypeError`` with the offending keys (the shim's error message)."""
        unknown = set(kwargs) - set(cls.field_names())
        if unknown:
            raise TypeError(f"unknown ServingConfig field(s): "
                            f"{sorted(unknown)}; valid fields are "
                            f"{list(cls.field_names())}")
        return cls(**kwargs)

    def to_kwargs(self) -> dict:
        """The loose-kwargs form, bit-exact round trip with
        :meth:`from_kwargs` (``from_kwargs(**cfg.to_kwargs()) == cfg``)."""
        return dataclasses.asdict(self)

    def replace(self, **changes) -> "ServingConfig":
        """A copy with ``changes`` applied (re-validated)."""
        return dataclasses.replace(self, **changes)


def resolve_config(config: Optional[ServingConfig],
                   core_kw: dict) -> ServingConfig:
    """The helper-level construction contract shared by ``make_sim_core`` /
    ``simulate`` / ``Engine`` / ``serve``: either an explicit
    ``config=ServingConfig(...)`` or loose core keywords (translated through
    :meth:`ServingConfig.from_kwargs` — same validation, no deprecation
    warning, since the helpers are a blessed construction path), never
    both."""
    if config is None:
        return ServingConfig.from_kwargs(**core_kw)
    if core_kw:
        raise TypeError(f"pass either config=ServingConfig(...) or loose "
                        f"core keywords, not both (got config= and "
                        f"{sorted(core_kw)})")
    return config
