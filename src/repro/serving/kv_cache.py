"""KV-cache block accounting (vLLM-style paged bookkeeping, TPU-adapted).

vLLM's PagedAttention maps logical KV blocks to scattered physical blocks in
GPU memory. On TPU, static shapes win: the engine keeps one contiguous
fixed-length cache lane per running slot, and this allocator reproduces the
*accounting* semantics (admission control, capacity back-pressure, free-list
reuse) over those lanes' block budgets (DESIGN.md §4). The scheduler consults
``can_allocate`` before admitting — a request that would exceed the cache
budget stays in W, exactly like vLLM deferring on OOM.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict


# Sentinel capacity for accounting-only allocators that never back-pressure
# (the simulator's default: memory-unbounded unless a budget is requested).
UNBOUNDED_BLOCKS = 1 << 60


@dataclass
class BlockAllocator:
    total_blocks: int
    block_size: int = 16
    _used: Dict[int, int] = field(default_factory=dict)   # req_id -> blocks

    @classmethod
    def unbounded(cls, block_size: int = 16) -> "BlockAllocator":
        return cls(total_blocks=UNBOUNDED_BLOCKS, block_size=block_size)

    def blocks_for(self, tokens: int) -> int:
        return -(-max(tokens, 1) // self.block_size)

    @property
    def free_blocks(self) -> int:
        return self.total_blocks - sum(self._used.values())

    @property
    def used_blocks(self) -> int:
        return sum(self._used.values())

    def reserved(self, req_id: int) -> int:
        """Blocks currently held by ``req_id`` (0 if none)."""
        return self._used.get(req_id, 0)

    def can_allocate(self, tokens: int) -> bool:
        return self.blocks_for(tokens) <= self.free_blocks

    def allocate(self, req_id: int, tokens: int) -> None:
        need = self.blocks_for(tokens)
        if need > self.free_blocks:
            raise MemoryError(f"KV cache exhausted: need {need}, "
                              f"free {self.free_blocks}")
        self._used[req_id] = need

    def extend(self, req_id: int, total_tokens: int) -> bool:
        """Grow a request's reservation; False if capacity exceeded."""
        need = self.blocks_for(total_tokens)
        delta = need - self._used.get(req_id, 0)
        if delta > self.free_blocks:
            return False
        self._used[req_id] = need
        return True

    def free(self, req_id: int) -> None:
        self._used.pop(req_id, None)
