"""KV-cache block accounting (vLLM-style paged bookkeeping, TPU-adapted).

vLLM's PagedAttention maps logical KV blocks to scattered physical blocks in
GPU memory. On TPU, static shapes win: the engine keeps one contiguous
fixed-length cache lane per running slot, and this allocator reproduces the
*accounting* semantics (admission control, capacity back-pressure, free-list
reuse) over those lanes' block budgets (DESIGN.md §4). The scheduler consults
``can_allocate`` before admitting — a request that would exceed the cache
budget stays in W, exactly like vLLM deferring on OOM.

**Refcounted prefix caching.** Blocks are identity-bearing and refcounted:
a request's reservation is a list of block ids, and the leading blocks of a
prompt can be *content-named* by a chained chunk hash
(:func:`prefix_chunk_hashes`). Two requests whose prompts share a token
prefix share the prefix's blocks — each holder increments the refcount, so
the shared blocks are counted once against the budget. When the last holder
frees, a content-named block is not returned to the free pool: it parks in
an LRU list of *cached* blocks, still indexed by its hash, and a later
request with the same prefix re-acquires it (a **prefix hit** — the serving
core then starts prefill at the cached offset instead of token 0). Cached
blocks count as free capacity: allocation under pressure reclaims them
oldest-first, unregistering the hash and notifying ``evict listeners`` (the
real engine drops its stored KV fragment in lockstep).

A freshly registered hash is not hitable until the owner *commits* it
(:meth:`BlockAllocator.commit`) — the serving core commits a request's
prompt blocks when its prefill completes, so a hit always refers to KV that
is actually resident somewhere, never to a prompt still streaming in.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Sequence, Set


# Sentinel capacity for accounting-only allocators that never back-pressure
# (the simulator's default: memory-unbounded unless a budget is requested).
UNBOUNDED_BLOCKS = 1 << 60


def prefix_chunk_hashes(token_ids: Sequence[int], block_size: int) -> List[int]:
    """Chained content hashes of the *full* ``block_size``-token chunks.

    ``out[i]`` names the entire prefix ``token_ids[: (i+1) * block_size]``
    (each link hashes the previous link plus the chunk's tokens, vLLM's
    prefix-hash scheme), so equal hashes at index i mean the whole prefix up
    to that block boundary is identical — a chain match is a prefix match.
    The trailing partial chunk is never hashed: only whole blocks are
    shareable. Deterministic across processes (pure int tuple hashing).
    """
    out: List[int] = []
    h = 0
    for i in range(0, len(token_ids) - block_size + 1, block_size):
        h = hash((h,) + tuple(token_ids[i:i + block_size]))
        out.append(h)
    return out


@dataclass
class BlockAllocator:
    total_blocks: int
    block_size: int = 16
    # req_id -> owned block ids, in prompt order (leading ids may be shared)
    _req_blocks: Dict[int, List[int]] = field(default_factory=dict)
    _refcount: Dict[int, int] = field(default_factory=dict)   # only rc >= 1
    _hash_block: Dict[int, int] = field(default_factory=dict)  # hash -> block
    _block_hash: Dict[int, int] = field(default_factory=dict)  # block -> hash
    _committed: Set[int] = field(default_factory=set)          # hitable blocks
    _lru: "OrderedDict[int, None]" = field(default_factory=OrderedDict)
    _free_pool: List[int] = field(default_factory=list)        # recycled ids
    _minted: int = 0                                           # ids ever made
    _evict_listeners: List[Callable[[int], None]] = field(default_factory=list)

    @classmethod
    def unbounded(cls, block_size: int = 16) -> "BlockAllocator":
        return cls(total_blocks=UNBOUNDED_BLOCKS, block_size=block_size)

    def blocks_for(self, tokens: int) -> int:
        return -(-max(tokens, 1) // self.block_size)

    # ------------------------------------------------------------ accounting
    @property
    def used_blocks(self) -> int:
        """Distinct blocks referenced by at least one request (shared prefix
        blocks are counted once — that is the point of sharing)."""
        return len(self._refcount)

    @property
    def cached_blocks(self) -> int:
        """Unreferenced content-named blocks parked in the LRU list. They
        count as *free* capacity (allocation reclaims them on demand)."""
        return len(self._lru)

    @property
    def free_blocks(self) -> int:
        return self.total_blocks - self.used_blocks

    def reserved(self, req_id: int) -> int:
        """Blocks currently held by ``req_id`` (0 if none)."""
        return len(self._req_blocks.get(req_id, ()))

    def block_table(self, req_id: int) -> List[int]:
        """The request's physical block ids in logical (prompt) order — the
        per-sequence block table a paged backend indexes its KV pool with.
        Leading entries may alias another request's blocks (shared prefix);
        a copy, so callers can pad/truncate freely."""
        return list(self._req_blocks.get(req_id, ()))

    def add_evict_listener(self, fn: Callable[[int], None]) -> None:
        """``fn(hash)`` fires when a cached block's content is dropped (LRU
        reclaim or release of an uncommitted owner) — backends keep their
        hash-keyed KV stores in lockstep with the accounting."""
        self._evict_listeners.append(fn)

    # --------------------------------------------------------- prefix lookup
    def _match(self, hashes: Sequence[int]) -> List[int]:
        """Longest committed chain prefix present in the index, as block ids
        (stops at the first missing or uncommitted link)."""
        out: List[int] = []
        for h in hashes:
            b = self._hash_block.get(h)
            if b is None or b not in self._committed:
                break
            out.append(b)
        return out

    def cached_prefix_blocks(self, hashes: Sequence[int]) -> int:
        """How many leading blocks of this hash chain a request could share
        right now (hitable = registered *and* committed)."""
        return len(self._match(hashes))

    def tracked(self, h: int) -> bool:
        """Whether a block is content-named by ``h`` (committed or not) —
        backends store KV fragments only for tracked hashes, so the eviction
        listener is guaranteed to fire for everything they hold."""
        return h in self._hash_block

    def can_allocate(self, tokens: int, hashes: Sequence[int] = ()) -> bool:
        need = self.blocks_for(tokens)
        shared = self._match(hashes[:need])
        from_lru = sum(1 for b in shared if b in self._lru)
        return need - len(shared) <= self.free_blocks - from_lru

    # ----------------------------------------------------------- allocation
    def _take_block(self) -> int:
        """A fresh unreferenced block id: recycled, newly minted, or an LRU
        cached block reclaimed (its hash is dropped + listeners notified)."""
        if self._free_pool:
            return self._free_pool.pop()
        if self._minted < self.total_blocks:
            self._minted += 1
            return self._minted - 1
        b, _ = self._lru.popitem(last=False)     # least recently used
        self._release(b)
        return self._free_pool.pop()

    def _release(self, b: int) -> None:
        """Drop a block's content identity and recycle its id."""
        h = self._block_hash.pop(b, None)
        if h is not None and self._hash_block.get(h) == b:
            del self._hash_block[h]
            for fn in self._evict_listeners:
                fn(h)
        self._committed.discard(b)
        self._free_pool.append(b)

    def _decref(self, b: int) -> None:
        self._refcount[b] -= 1
        if self._refcount[b]:
            return
        del self._refcount[b]
        if b in self._committed and self._block_hash.get(b) is not None:
            self._lru[b] = None                  # park, most-recently-used end
        else:
            self._release(b)                     # anonymous / never committed

    def allocate(self, req_id: int, tokens: int,
                 hashes: Sequence[int] = ()) -> int:
        """Reserve ``blocks_for(tokens)`` blocks for ``req_id``; the leading
        ``len(hashes)`` blocks are content-named by the prompt's chunk-hash
        chain. Committed chain links already in the index are *shared*
        (refcount bump, no new capacity) instead of newly claimed; returns
        how many blocks were shared — the caller's prefix hit, in blocks.
        Re-allocating for a held ``req_id`` replaces its reservation.
        """
        if req_id in self._req_blocks:
            self.free(req_id)
        need = self.blocks_for(tokens)
        shared = self._match(hashes[:need])
        from_lru = sum(1 for b in shared if b in self._lru)
        if need - len(shared) > self.free_blocks - from_lru:
            raise MemoryError(f"KV cache exhausted: need {need - len(shared)}, "
                              f"free {self.free_blocks - from_lru}")
        blocks: List[int] = []
        for b in shared:                          # prefix hit: share, pin
            self._lru.pop(b, None)
            self._refcount[b] = self._refcount.get(b, 0) + 1
            blocks.append(b)
        for i in range(len(shared), need):        # miss / tail: claim fresh
            b = self._take_block()
            self._refcount[b] = 1
            if i < len(hashes) and hashes[i] not in self._hash_block:
                # first writer wins: a concurrent identical prompt keeps its
                # duplicate blocks anonymous (they recycle on free)
                self._hash_block[hashes[i]] = b
                self._block_hash[b] = hashes[i]
            blocks.append(b)
        self._req_blocks[req_id] = blocks
        return len(shared)

    def commit(self, req_id: int) -> None:
        """Make ``req_id``'s content-named blocks hitable. Called by the
        serving core once the request's prompt is fully KV-resident — a
        prefix hit must never point at KV still streaming in."""
        for b in self._req_blocks.get(req_id, ()):
            h = self._block_hash.get(b)
            if h is not None and self._hash_block.get(h) == b:
                self._committed.add(b)

    def grow(self, req_id: int, n: int) -> bool:
        """Append ``n`` fresh anonymous blocks to a reservation (the
        incremental decode-phase allocation unit: one table entry per call
        site, never content-shared). False — with the reservation intact —
        when ``n`` exceeds free capacity; LRU-parked cached blocks count as
        free and are reclaimed on demand, exactly like :meth:`allocate`."""
        if n <= 0:
            return True
        if n > self.free_blocks:
            return False
        cur = self._req_blocks.setdefault(req_id, [])
        for _ in range(n):
            b = self._take_block()
            self._refcount[b] = 1
            cur.append(b)
        return True

    def extend(self, req_id: int, total_tokens: int) -> bool:
        """Grow (or shrink) a request's reservation to ``total_tokens``;
        False if growth exceeds capacity. Growth appends anonymous blocks
        (via :meth:`grow`) — decode-phase KV is per-request, never
        content-shared."""
        need = self.blocks_for(total_tokens)
        cur = self._req_blocks.setdefault(req_id, [])
        delta = need - len(cur)
        if delta > 0:
            return self.grow(req_id, delta)
        for _ in range(-delta):
            self._decref(cur.pop())
        return True

    def free(self, req_id: int) -> None:
        """Release a reservation: every block drops one reference. Committed
        content-named blocks whose refcount reaches zero park in the LRU
        cache (a later identical prefix re-acquires them); the rest recycle
        into the free pool immediately."""
        for b in self._req_blocks.pop(req_id, ()):
            self._decref(b)

    def clear_cache(self) -> int:
        """Drop every LRU-parked cached block (crash/cold-restart semantics:
        the replica's KV memory is gone, so its warm prefixes must stop
        being hitable). Fires the evict listeners for each dropped hash so
        backends discard their fragments in lockstep; live reservations are
        untouched — callers free those per request first. Returns the number
        of blocks dropped."""
        n = 0
        while self._lru:
            b, _ = self._lru.popitem(last=False)
            self._release(b)
            n += 1
        return n
