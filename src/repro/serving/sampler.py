"""Token samplers for the serving engine: greedy / temperature / top-k.

Pure functions over logits so they jit/vmap cleanly inside the engine's
decode program. The paper's decoding setup (temperature=0.7, top-p=0.9) is
what its δ calibration assumes; the engine defaults to greedy for determinism
in tests and supports the paper's setup via ``SamplerConfig``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0        # 0 = greedy
    top_k: int = 0                  # 0 = full vocab
    top_p: float = 1.0              # nucleus; 1.0 = off


def sample(logits: jax.Array, key: jax.Array,
           cfg: SamplerConfig = SamplerConfig()) -> jax.Array:
    """logits: (..., V) → token ids (...,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k and cfg.top_k < lf.shape[-1]:
        kth = jnp.sort(lf, axis=-1)[..., -cfg.top_k][..., None]
        lf = jnp.where(lf < kth, -jnp.inf, lf)
    if cfg.top_p < 1.0:
        sorted_lf = jnp.sort(lf, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_lf, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative mass >= top_p: find cutoff logit
        keep = cum - probs < cfg.top_p
        cutoff = jnp.min(jnp.where(keep, sorted_lf, jnp.inf), axis=-1,
                         keepdims=True)
        lf = jnp.where(lf < cutoff, -jnp.inf, lf)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)
