"""Real JAX serving engine: continuous batching over a slot-resident KV cache.

This is the integration the paper performs in vLLM, rebuilt TPU-idiomatically
(DESIGN.md §4): a fixed-capacity running batch of ``max_batch`` slots with
static shapes; admission = one-request prefill + ``at[slot].set`` into the
batch cache; completion = slot free + allocator release. Decode is a single
jitted, per-slot-position ``vmap`` of the model's one-token step, so slots at
different sequence positions advance together in one TPU program.

The scheduler (and therefore PARS itself) is byte-identical to the simulator
path — only the clock is real here.

Prompt handling: prompts are hash-tokenized and padded/truncated to a fixed
``prompt_len`` bucket. Completion length follows the request's ground-truth
``true_length`` (the forced-length protocol, DESIGN.md §3) — the engine
generates real tokens, but *when* a request finishes is the workload's ground
truth, exactly as in the paper's trace-driven evaluation.
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.predictor.tokenizer import HashTokenizer
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.models import transformer as tfm
from repro.serving.kv_cache import BlockAllocator
from repro.serving.metrics import LatencyReport, report
from repro.serving.sampler import SamplerConfig, sample


class Engine:
    def __init__(self, cfg: ModelConfig, params, scheduler: Scheduler, *,
                 cache_len: int = 512, prompt_len: int = 32,
                 tokenizer: Optional[HashTokenizer] = None,
                 allocator: Optional[BlockAllocator] = None,
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0):
        self.cfg = cfg
        self.sampler = sampler
        self._key = jax.random.PRNGKey(seed)
        self.params = params
        self.scheduler = scheduler
        self.cache_len = cache_len
        self.prompt_len = prompt_len
        self.tok = tokenizer or HashTokenizer(
            vocab_size=min(cfg.vocab_size, 2048), max_len=prompt_len)
        s = scheduler.max_batch
        self.allocator = allocator or BlockAllocator(
            total_blocks=s * (-(-cache_len // 16)), block_size=16)

        # --- slot state ------------------------------------------------------
        self.slot_req: List[Optional[Request]] = [None] * s
        self.slot_tokens = jnp.zeros((s, 1), jnp.int32)
        row_cache = jax.eval_shape(lambda: tfm.init_cache(cfg, 1, cache_len))
        self.cache = jax.tree.map(
            lambda l: jnp.zeros((s,) + l.shape, l.dtype), row_cache)
        self.finished: List[Request] = []

        # --- jitted programs ---------------------------------------------------
        sampler_cfg = sampler

        @jax.jit
        def _prefill(params, tokens, key):
            logits, cache, _ = tfm.forward_seq(
                params, cfg, tokens, build_cache=True, cache_len=cache_len,
                remat="none")
            nxt = sample(logits[:, -1], key, sampler_cfg)
            return nxt, cache

        @jax.jit
        def _decode_all(params, cache, tokens, key):
            keys = jax.random.split(key, tokens.shape[0])
            def one(cache_row, token_row, k):
                logits, new_cache = tfm.decode_step(params, cfg, cache_row,
                                                    token_row[None])
                nxt = sample(logits[0], k, sampler_cfg)
                return nxt, new_cache
            nxt, new_cache = jax.vmap(one)(cache, tokens, keys)
            return nxt[:, None], new_cache

        self._prefill = _prefill
        self._decode_all = _decode_all
        self._pending: List[Request] = []

    # -------------------------------------------------------------------- api
    def submit(self, requests: Sequence[Request]) -> None:
        self._pending.extend(sorted(requests, key=lambda r: r.arrival_time))

    def _encode_prompt(self, prompt: str) -> jnp.ndarray:
        ids = self.tok.encode(prompt)[: self.prompt_len]
        ids = ids + [0] * (self.prompt_len - len(ids))
        arr = np.asarray(ids, np.int32) % self.cfg.vocab_size
        return jnp.asarray(arr)[None]

    def _admit(self, req: Request, slot: int) -> None:
        self.allocator.allocate(
            req.req_id, self.prompt_len + min(req.true_length, self.cache_len))
        self._key, sub = jax.random.split(self._key)
        nxt, row_cache = self._prefill(self.params,
                                       self._encode_prompt(req.prompt), sub)
        self.cache = jax.tree.map(
            lambda full, row: full.at[slot].set(
                jnp.broadcast_to(row, full.shape[1:])), self.cache, row_cache)
        self.slot_tokens = self.slot_tokens.at[slot].set(nxt[:1])
        self.slot_req[slot] = req

    def _retire(self, slot: int, now: float) -> None:
        req = self.slot_req[slot]
        req.finish_time = now
        self.allocator.free(req.req_id)
        self.slot_req[slot] = None
        self.finished.append(req)

    # -------------------------------------------------------------------- run
    def run(self, *, time_scale: float = 1.0, log_every: float = 0.0,
            log_fn=print) -> List[Request]:
        """Serve everything submitted; returns finished requests.

        ``time_scale`` multiplies trace arrival times (replay a GPU-scale
        trace on CPU without idling)."""
        t0 = time.perf_counter()
        clock = lambda: time.perf_counter() - t0
        last_log = 0.0
        total = len(self._pending)
        while self._pending or self.scheduler.has_work:
            now = clock()
            while (self._pending
                   and self._pending[0].arrival_time * time_scale <= now):
                r = self._pending.pop(0)
                r.arrival_time *= time_scale
                self.scheduler.add_request(r)
            if not self.scheduler.has_work:
                time.sleep(1e-4)
                continue

            # admission: scheduler ranks; engine enforces the KV budget
            admitted = self.scheduler.schedule(now)
            deferred = []
            for req in admitted:
                need = self.prompt_len + min(req.true_length, self.cache_len)
                if not self.allocator.can_allocate(need):
                    deferred.append(req)
                    continue
                slot = self.slot_req.index(None)
                self._admit(req, slot)
                req.tokens_done = 1               # prefill emits token 1
                req.first_token_time = clock()
                if req.finished:                  # true_length == 1
                    self._retire(slot, clock())
            if deferred:                          # back-pressure → requeue
                self.scheduler.running = [r for r in self.scheduler.running
                                          if r not in deferred]
                self.scheduler.waiting = deferred + self.scheduler.waiting

            if any(s is not None for s in self.slot_req):
                self._key, sub = jax.random.split(self._key)
                self.slot_tokens, self.cache = self._decode_all(
                    self.params, self.cache, self.slot_tokens, sub)
                jax.block_until_ready(self.slot_tokens)
                now = clock()
                for slot, req in enumerate(self.slot_req):
                    if req is None:
                        continue
                    req.tokens_done += 1
                    if req.finished:
                        self._retire(slot, now)
                self.scheduler.retire_finished(now)

            if log_every and clock() - last_log > log_every:
                last_log = clock()
                log_fn(f"[engine t={last_log:6.1f}s] "
                       f"running={len(self.scheduler.running)} "
                       f"waiting={len(self.scheduler.waiting)} "
                       f"finished={len(self.finished)}/{total}")
        return self.finished


def serve(cfg: ModelConfig, params, requests: Sequence[Request], policy, *,
          max_batch: int = 8, cache_len: int = 256, prompt_len: int = 32,
          starvation_threshold: float = 120.0, time_scale: float = 1.0,
          log_every: float = 0.0) -> LatencyReport:
    """Convenience wrapper: fresh engine + scheduler, serve, report."""
    sched = Scheduler(policy=policy, max_batch=max_batch,
                      starvation_threshold=starvation_threshold)
    eng = Engine(cfg, params, sched, cache_len=cache_len,
                 prompt_len=prompt_len)
    eng.submit(requests)
    finished = eng.run(time_scale=time_scale, log_every=log_every)
    assert len(finished) == len(requests), (len(finished), len(requests))
    return report(policy.name, finished)
