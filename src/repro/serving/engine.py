"""Real JAX serving backend: continuous batching over a slot-resident KV cache.

This is the integration the paper performs in vLLM, rebuilt TPU-idiomatically
(DESIGN.md §4) on top of the shared :class:`~repro.serving.core.ServingCore`
step loop: a fixed-capacity running batch of ``max_batch`` slots with static
shapes. The scheduler (and therefore PARS itself) is byte-identical to the
simulator path — only the backend and the clock differ.

Admission is **batched and prompt-length-bucketed**: the K requests admitted
in a cycle are padded to a small set of power-of-two token buckets and each
bucket runs as *one* jitted ``forward_seq`` (batch dimension also padded to a
power of two, so the set of compiled shapes is bounded) instead of K
sequential per-request dispatches. Decode gathers only the *active* slots
into a power-of-two-sized compact batch — idle lanes are never computed —
runs one jitted step, and scatters back. Padding lanes replay an active lane
with the same per-slot RNG key, so duplicate scatter writes are idempotent.

Prompt handling: prompts are hash-tokenized into their bucket. Completion
length follows the request's ground-truth ``true_length`` (the forced-length
protocol, DESIGN.md §3) — the engine generates real tokens, but *when* a
request finishes is the workload's ground truth, exactly as in the paper's
trace-driven evaluation.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.predictor.tokenizer import HashTokenizer
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.models import transformer as tfm
from repro.serving.core import ServingCore, WallClock
from repro.serving.kv_cache import BlockAllocator
from repro.serving.metrics import LatencyReport, report
from repro.serving.sampler import SamplerConfig, sample


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class RealBackend:
    """Jitted prefill/decode over a slot-resident cache (ExecutionBackend)."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int,
                 cache_len: int = 512, prompt_len: int = 32,
                 tokenizer: Optional[HashTokenizer] = None,
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0,
                 bucketed: bool = True, min_bucket: int = 8):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.prompt_len = prompt_len
        self.bucketed = bucketed
        self.min_bucket = min(min_bucket, prompt_len)
        self.tok = tokenizer or HashTokenizer(
            vocab_size=min(cfg.vocab_size, 2048), max_len=prompt_len)
        self._key = jax.random.PRNGKey(seed)
        self.core: Optional[ServingCore] = None

        # --- slot state ------------------------------------------------------
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self._slot_of: Dict[int, int] = {}
        self.slot_tokens = jnp.zeros((max_batch, 1), jnp.int32)
        row_cache = jax.eval_shape(lambda: tfm.init_cache(cfg, 1, cache_len))
        self.cache = jax.tree.map(
            lambda l: jnp.zeros((max_batch,) + l.shape, l.dtype), row_cache)

        # --- instrumentation -------------------------------------------------
        self.prefill_dispatches = 0   # jitted forward_seq launches
        self.prefill_requests = 0     # requests admitted through them
        self.prefill_seconds = 0.0    # wall time spent in admission

        # --- jitted programs -------------------------------------------------
        sampler_cfg = sampler

        @jax.jit
        def _prefill_bucket(params, tokens, slot_ids, key):
            """One bucket: tokens (B, bucket_len) → (next token (B,), cache).

            Per-slot keys (``fold_in``) make padding lanes that replay lane 0
            sample the same token, keeping duplicate scatters idempotent."""
            logits, cache, _ = tfm.forward_seq(
                params, cfg, tokens, build_cache=True, cache_len=cache_len,
                remat="none")
            keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(slot_ids)
            nxt = jax.vmap(lambda lg, k: sample(lg, k, sampler_cfg))(
                logits[:, -1], keys)
            return nxt, cache

        @jax.jit
        def _place(full_cache, bucket_cache, full_tokens, nxt, slot_ids):
            """Scatter a prefilled bucket's rows into their slots."""
            def put(full, new):
                if new.ndim == 0:          # cache position: scalar per slot
                    return full.at[slot_ids].set(new)
                # (L, B, ...) bucket leaf → (B, L, 1, ...) slot rows
                return full.at[slot_ids].set(
                    jnp.expand_dims(jnp.moveaxis(new, 1, 0), 2))
            new_cache = jax.tree.map(put, full_cache, bucket_cache)
            return new_cache, full_tokens.at[slot_ids].set(nxt[:, None])

        @jax.jit
        def _decode_active(params, cache, tokens, idx, key):
            """Gather active slots ``idx`` (padded to a power of two with
            duplicates of idx[0]), decode one token each, scatter back."""
            sub_cache = jax.tree.map(lambda l: l[idx], cache)
            sub_tokens = tokens[idx]
            keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)

            def one(cache_row, token_row, k):
                logits, new_row = tfm.decode_step(params, cfg, cache_row,
                                                  token_row[None])
                return sample(logits[0], k, sampler_cfg), new_row

            nxt, new_sub = jax.vmap(one)(sub_cache, sub_tokens, keys)
            new_cache = jax.tree.map(lambda full, sub: full.at[idx].set(sub),
                                     cache, new_sub)
            return tokens.at[idx].set(nxt[:, None]), new_cache

        self._prefill_bucket = _prefill_bucket
        self._place = _place
        self._decode_active = _decode_active

    # -------------------------------------------------------------- protocol
    def attach(self, core: ServingCore) -> None:
        self.core = core

    def kv_demand(self, req: Request) -> int:
        return self.prompt_len + min(req.true_length, self.cache_len)

    def _bucket_len(self, n_tokens: int) -> int:
        if not self.bucketed:
            return self.prompt_len
        return min(self.prompt_len, _next_pow2(max(n_tokens, self.min_bucket)))

    def bucket_lens(self) -> List[int]:
        if not self.bucketed:
            return [self.prompt_len]
        lens, b = [], self.min_bucket
        while b < self.prompt_len:
            lens.append(b)
            b *= 2
        return lens + [self.prompt_len]

    def warmup(self) -> float:
        """Pre-compile the (bucket_len × batch-size) shape grid, vLLM-style,
        so steady-state admission never pays jit. Returns wall seconds."""
        t0 = time.perf_counter()
        key = jax.random.PRNGKey(0)
        sizes, b = [], 1
        while b < _next_pow2(self.max_batch):
            sizes.append(b)
            b *= 2
        sizes.append(_next_pow2(self.max_batch))
        for bl in self.bucket_lens():
            for bsz in sizes:
                tokens = jnp.zeros((bsz, bl), jnp.int32)
                slots = jnp.zeros((bsz,), jnp.int32)
                nxt, cache = self._prefill_bucket(self.params, tokens, slots,
                                                  key)
                self._place(self.cache, cache, self.slot_tokens, nxt, slots)
        for bsz in sizes:
            out, _ = self._decode_active(self.params, self.cache,
                                         self.slot_tokens,
                                         jnp.zeros((bsz,), jnp.int32), key)
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    def _now(self, fallback: float) -> float:
        return self.core.clock.now() if self.core is not None else fallback

    def prefill(self, admitted: Sequence[Request], now: float) -> float:
        if not admitted:
            return now
        t0 = time.perf_counter()
        encoded = [(r, [t % self.cfg.vocab_size
                        for t in self.tok.encode(r.prompt)[:self.prompt_len]])
                   for r in admitted]
        if self.bucketed:
            groups: Dict[int, list] = {}
            for req, ids in encoded:
                groups.setdefault(self._bucket_len(len(ids)), []).append(
                    (req, ids))
            batches = list(groups.items())
        else:                          # sequential: one dispatch per request
            batches = [(self.prompt_len, [pair]) for pair in encoded]
        for bucket_len, group in batches:
            b = _next_pow2(len(group))
            tokens = np.zeros((b, bucket_len), np.int32)
            slots = np.zeros((b,), np.int32)
            for j, (req, ids) in enumerate(group):
                tokens[j, :len(ids)] = ids
                slot = self.slot_req.index(None)
                self.slot_req[slot] = req
                self._slot_of[req.req_id] = slot
                slots[j] = slot
            tokens[len(group):] = tokens[0]     # padding lanes replay lane 0
            slots[len(group):] = slots[0]
            self._key, sub = jax.random.split(self._key)
            slots_j = jnp.asarray(slots)
            nxt, bucket_cache = self._prefill_bucket(
                self.params, jnp.asarray(tokens), slots_j, sub)
            self.cache, self.slot_tokens = self._place(
                self.cache, bucket_cache, self.slot_tokens, nxt, slots_j)
            self.prefill_dispatches += 1
            self.prefill_requests += len(group)
        jax.block_until_ready(self.slot_tokens)
        self.prefill_seconds += time.perf_counter() - t0
        now = self._now(now)
        for req, _ in encoded:
            # recompute semantics on re-admission after preemption: decode
            # progress and TTFT are preserved, matching SimBackend
            if req.tokens_done == 0:
                req.tokens_done = 1             # prefill emits token 1
            if req.first_token_time is None:
                req.first_token_time = now
        return now

    def decode(self, now: float) -> float:
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return now
        idx = np.asarray(
            active + [active[0]] * (_next_pow2(len(active)) - len(active)),
            np.int32)
        self._key, sub = jax.random.split(self._key)
        self.slot_tokens, self.cache = self._decode_active(
            self.params, self.cache, self.slot_tokens, jnp.asarray(idx), sub)
        jax.block_until_ready(self.slot_tokens)
        for i in active:
            self.slot_req[i].tokens_done += 1
        return self._now(now)

    def release(self, req: Request) -> None:
        slot = self._slot_of.pop(req.req_id, None)
        if slot is not None:
            self.slot_req[slot] = None


class Engine:
    """RealBackend + ServingCore wiring (the historical engine interface)."""

    def __init__(self, cfg: ModelConfig, params, scheduler: Scheduler, *,
                 cache_len: int = 512, prompt_len: int = 32,
                 tokenizer: Optional[HashTokenizer] = None,
                 allocator: Optional[BlockAllocator] = None,
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0,
                 bucketed: bool = True):
        s = scheduler.max_batch
        self.scheduler = scheduler
        self.backend = RealBackend(
            cfg, params, max_batch=s, cache_len=cache_len,
            prompt_len=prompt_len, tokenizer=tokenizer, sampler=sampler,
            seed=seed, bucketed=bucketed)
        self.allocator = allocator or BlockAllocator(
            total_blocks=s * (-(-cache_len // 16)), block_size=16)
        self.core = ServingCore(scheduler, self.backend,
                                allocator=self.allocator)

    # -------------------------------------------------------------------- api
    @property
    def finished(self) -> List[Request]:
        return self.core.finished

    def submit(self, requests: Sequence[Request]) -> None:
        self.core.submit(requests)

    def warmup(self) -> float:
        return self.backend.warmup()

    def run(self, *, time_scale: float = 1.0, log_every: float = 0.0,
            log_fn=print) -> List[Request]:
        """Serve everything submitted; returns finished requests.

        ``time_scale`` multiplies trace arrival times (replay a GPU-scale
        trace on CPU without idling)."""
        if time_scale != 1.0:
            for r in self.core._pending:
                r.arrival_time *= time_scale
        self.core.clock = WallClock()           # origin = serving start
        return self.core.run(log_every=log_every, log_fn=log_fn)


def serve(cfg: ModelConfig, params, requests: Sequence[Request], policy, *,
          max_batch: int = 8, cache_len: int = 256, prompt_len: int = 32,
          starvation_threshold: float = 120.0, time_scale: float = 1.0,
          log_every: float = 0.0, bucketed: bool = True,
          kv_blocks: Optional[int] = None) -> LatencyReport:
    """Convenience wrapper: fresh engine + scheduler, serve, report."""
    sched = Scheduler(policy=policy, max_batch=max_batch,
                      starvation_threshold=starvation_threshold)
    allocator = BlockAllocator(kv_blocks, 16) if kv_blocks else None
    eng = Engine(cfg, params, sched, cache_len=cache_len,
                 prompt_len=prompt_len, allocator=allocator,
                 bucketed=bucketed)
    eng.submit(requests)
    finished = eng.run(time_scale=time_scale, log_every=log_every)
    assert len(finished) == len(requests), (len(finished), len(requests))
    return report(policy.name, finished)
