"""Real JAX serving backend: continuous batching over a slot-resident KV cache.

This is the integration the paper performs in vLLM, rebuilt TPU-idiomatically
(DESIGN.md §4) on top of the shared :class:`~repro.serving.core.ServingCore`
step loop: a fixed-capacity running batch of ``max_batch`` slots with static
shapes. The scheduler (and therefore PARS itself) is byte-identical to the
simulator path — only the backend and the clock differ.

Admission is **batched and prompt-length-bucketed**: the K requests admitted
in a cycle are padded to a small set of power-of-two token buckets and each
bucket runs as *one* jitted ``forward_seq`` (batch dimension also padded to a
power of two, so the set of compiled shapes is bounded) instead of K
sequential per-request dispatches. Decode gathers only the *active* slots
into a power-of-two-sized compact batch — idle lanes are never computed —
runs one jitted step, and scatters back. Padding lanes replay an active lane
with the same per-slot RNG key, so duplicate scatter writes are idempotent.

**Chunked prefill** (``prefill_chunk_tokens`` on :class:`Engine` /
:func:`serve`): the core plans mixed steps and this backend executes each
planned ``(req, start, end)`` chunk. A request's *first* chunk reuses the
bucketed ``_prefill_bucket`` + ``_place`` pair (a chunk starting at offset 0
is just a short prefill); *continuation* chunks run ``_extend_chunk``, which
writes the chunk's K/V into the request's cache lane at its current offset
and attends the chunk's queries over the already-resident prefix — exact
continuation, so chunked and unchunked serving produce identical greedy
outputs. A slot only joins the decode batch once its prompt is fully
resident (``core.decode_ready``). Chunked prefill requires an
attention-family model (DENSE/MoE/VLM) and an append-buffer cache
(``prompt_len <= cache_len``, no sliding window); recurrent families carry
cross-chunk state that ``forward_seq`` does not externalize.

**Paged KV** (``paged`` on :class:`Engine` / :func:`serve`; auto-on for
attention-family, non-enc-dec, non-sliding-window models): KV lives in one
global block pool ``(total_blocks + 1, L, block_size, KH, dh)`` per K and V
instead of per-slot lanes, indexed by each request's allocator block table
(plus a trailing *null* block that absorbs padded-table writes and whose
reads are always masked). Dispatches gather a request's table into a
contiguous lane, run the same forward/decode math as contiguous mode — rows
past the lane ``pos`` are masked to an exact constant, so outputs are
bit-identical, not approximately equal (``tests/test_paged_decode.py``) —
and scatter back only the blocks the step wrote. See docs/architecture.md
§"Paged KV" for the table lifecycle and the incremental
(``kv_reservation="incremental"``) grow-or-preempt contract.

**Prefix caching** (``prefix_caching=True`` on :class:`Engine` /
:func:`serve`): the core's allocator refcounts content-named KV blocks. In
paged mode a hit is **zero-copy**: the allocator aliased the committed
prefix blocks into the new request's table at reservation time and the pool
rows are the cache, so the backend just claims a slot and resumes prefill at
the cached offset (``prefix_tokens_copied`` stays 0). In contiguous mode
(``paged=False``) the backend keeps the historical hash-keyed **fragment
store**: per-block K/V slices are copied out of a donor lane at prompt
completion, and a hit concatenates the chain's fragments into the new lane
at ``[0, cached)`` before running ``_extend_chunk`` on the non-shared
suffix. Because attention at position i depends only on tokens ``<= i``,
the donor's prefix KV is bit-identical to what the recipient would have
computed itself — greedy outputs with caching on equal caching off
token-for-token (asserted in ``tests/test_prefix_caching.py``). The store
shrinks in lockstep with the allocator's LRU: an eviction listener drops the
fragment the moment accounting reclaims its block.

Prompt handling: prompts are hash-tokenized into their bucket. Completion
length follows the request's ground-truth ``true_length`` (the forced-length
protocol, DESIGN.md §3) — the engine generates real tokens, but *when* a
request finishes is the workload's ground truth, exactly as in the paper's
trace-driven evaluation.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DENSE, MOE, VLM, ModelConfig
from repro.core.predictor.tokenizer import HashTokenizer
from repro.core.scheduler.request import Request
from repro.core.scheduler.scheduler import Scheduler
from repro.models import transformer as tfm
from repro.serving.config import ServingConfig, resolve_config
from repro.serving.core import PrefillChunk, ServingCore, WallClock
from repro.serving.kv_cache import (UNBOUNDED_BLOCKS, BlockAllocator,
                                    prefix_chunk_hashes)
from repro.serving.metrics import LatencyReport, RunCounters, report
from repro.serving.sampler import SamplerConfig, sample


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class RealBackend:
    """Jitted prefill/decode over a slot-resident cache (ExecutionBackend)."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int,
                 cache_len: int = 512, prompt_len: int = 32,
                 tokenizer: Optional[HashTokenizer] = None,
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0,
                 bucketed: bool = True, min_bucket: int = 8,
                 record_tokens: bool = False, paged: bool = False):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.prompt_len = prompt_len
        self.bucketed = bucketed
        self.min_bucket = min(min_bucket, prompt_len)
        self.record_tokens = record_tokens
        self.paged = paged
        self.tok = tokenizer or HashTokenizer(
            vocab_size=min(cfg.vocab_size, 2048), max_len=prompt_len)
        self._key = jax.random.PRNGKey(seed)
        self.core: Optional[ServingCore] = None

        # --- slot state ------------------------------------------------------
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self._slot_of: Dict[int, int] = {}
        self._ids: Dict[int, List[int]] = {}    # req_id -> encoded prompt ids
        self.slot_tokens = jnp.zeros((max_batch, 1), jnp.int32)
        row_cache = jax.eval_shape(lambda: tfm.init_cache(cfg, 1, cache_len))
        self.cache = jax.tree.map(
            lambda l: jnp.zeros((max_batch,) + l.shape, l.dtype), row_cache)

        # --- prefix-cache fragment store (contiguous mode only) --------------
        # chunk-chain hash -> {"k": (L, block, kvH, D), "v": ...} device K/V of
        # one content-named block, copied out of a donor lane at prompt
        # completion; dropped via the allocator's eviction listener. Paged
        # mode has no store: a hit aliases pool blocks into the new table.
        self._prefix_store: Dict[int, dict] = {}

        # --- paged KV pool (built at attach: sized by the core's allocator) --
        # pools are (total_blocks + 1, L, block_size, KH, dh); the extra
        # trailing block is the *null* block — table padding that absorbs
        # out-of-reservation writes and whose reads are always masked
        self.k_pool = None
        self.v_pool = None
        self._null_block: Optional[int] = None
        self._lane_blocks: Optional[int] = None    # cache_len // block_size
        # req_id -> device-equivalent lane ``pos`` (tokens resident): set to
        # the prefill target at prompt completion, +1 per decode step —
        # mirrors the contiguous cache's per-slot ``pos`` leaf exactly,
        # including recompute re-admissions (where tokens_done is preserved
        # but the lane restarts at the target)
        self._pos: Dict[int, int] = {}

        # --- instrumentation -------------------------------------------------
        self.prefill_dispatches = 0   # jitted first-chunk forward_seq launches
        self.extend_dispatches = 0    # jitted continuation-chunk launches
        self.prefill_requests = 0     # requests whose prefill completed
        self.prefill_seconds = 0.0    # wall time spent in admission/prefill
        self.prefix_installs = 0      # lanes seeded from the fragment store
        self.prefix_tokens_copied = 0  # KV tokens installed instead of computed

        # --- jitted programs -------------------------------------------------
        sampler_cfg = sampler
        self._sampler_cfg = sampler

        @jax.jit
        def _prefill_bucket(params, tokens, slot_ids, key):
            """First-chunk prefill for one token bucket.

            ``tokens`` is (B, bucket_len) int32 — the admitted prompts of one
            power-of-two length bucket, zero-padded on the right (token id 0
            acts as the pad token) and with padding *lanes* replaying lane 0.
            Runs one full-sequence forward with ``build_cache=True``, so the
            returned cache pytree holds every layer's K/V for positions
            [0, bucket_len), already padded out to ``cache_len`` rows by
            ``prefill_cache`` and carrying ``pos = bucket_len``.

            Also samples each lane's next token from ``logits[:, -1]`` — the
            request's first output token *if* this bucket covers its whole
            (padded) prompt; for a partial first chunk the sample is discarded
            by the caller. Per-slot keys (``fold_in``) make padding lanes that
            replay lane 0 sample the same token, keeping duplicate scatter
            writes idempotent.

            Returns ``(next_token (B,), cache)`` where cache leaves are
            (L, B, cache_len, ...) plus the ``pos`` scalar."""
            logits, cache, _ = tfm.forward_seq(
                params, cfg, tokens, build_cache=True, cache_len=cache_len,
                remat="none")
            keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(slot_ids)
            nxt = jax.vmap(lambda lg, k: sample(lg, k, sampler_cfg))(
                logits[:, -1], keys)
            return nxt, cache

        @jax.jit
        def _place(full_cache, bucket_cache, full_tokens, nxt, slot_ids):
            """Scatter a prefilled bucket's rows into their cache slots.

            ``full_cache`` leaves are (max_batch, L, 1, cache_len, ...) — one
            fixed lane per slot; ``bucket_cache`` leaves arrive from
            ``_prefill_bucket`` as (L, B, cache_len, ...) (scan-stacked, batch
            second). ``put`` transposes each bucket leaf to slot-major and
            writes whole lanes at ``slot_ids``; the scalar ``pos`` leaf
            broadcasts to every written slot, recording how many prompt
            tokens are resident (the chunk offset that ``_extend_chunk`` and
            decode continue from). ``nxt`` lands in ``full_tokens`` as each
            slot's pending decode input. Duplicate ``slot_ids`` (padding
            lanes) write identical values, so the scatter is idempotent."""
            def put(full, new):
                if new.ndim == 0:          # cache position: scalar per slot
                    return full.at[slot_ids].set(new)
                # (L, B, ...) bucket leaf → (B, L, 1, ...) slot rows
                return full.at[slot_ids].set(
                    jnp.expand_dims(jnp.moveaxis(new, 1, 0), 2))
            new_cache = jax.tree.map(put, full_cache, bucket_cache)
            return new_cache, full_tokens.at[slot_ids].set(nxt[:, None])

        @jax.jit
        def _extend_chunk(params, full_cache, full_tokens, tokens, slot_ids,
                          commit, key):
            """Continuation-chunk prefill at each slot's current offset.

            ``tokens`` is (B, chunk_len) int32 — the *next* chunk_len prompt
            tokens of B partially prefilled requests (padding lanes replay
            lane 0). Gathers those slots' cache rows, runs
            ``tfm.forward_chunk`` per row under ``vmap`` — each row carries
            its own ``pos`` leaf, so requests at *different* prefill offsets
            batch together; the chunk's K/V are written into the lane at
            [pos, pos+chunk_len) and its queries attend over the resident
            prefix, making the continuation exact — and scatters the
            extended rows back.

            Samples each lane's next token from the chunk's last position
            and commits it into ``full_tokens`` only where ``commit`` is set
            — the lanes whose prompt this chunk completes (mid-prompt
            samples are meaningless and must not clobber a pending decode
            token). Duplicate padding lanes carry lane 0's commit flag, so
            the scatter stays idempotent. Returns
            ``(new_full_tokens, new_full_cache)``."""
            sub = jax.tree.map(lambda l: l[slot_ids], full_cache)
            keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(slot_ids)

            def one(cache_row, toks, k):
                logits, new_row = tfm.forward_chunk(params, cfg, toks[None],
                                                    cache_row)
                return sample(logits[0, -1], k, sampler_cfg), new_row

            nxt, new_sub = jax.vmap(one)(sub, tokens, keys)
            new_cache = jax.tree.map(
                lambda full, s: full.at[slot_ids].set(s), full_cache, new_sub)
            kept = jnp.where(commit[:, None], nxt[:, None],
                             full_tokens[slot_ids])
            return full_tokens.at[slot_ids].set(kept), new_cache

        @jax.jit
        def _decode_active(params, cache, tokens, idx, key):
            """One decode iteration over the *active* slots only.

            ``idx`` (B,) lists the decode-ready slots, padded to a power of
            two with duplicates of ``idx[0]`` so the compiled-shape set stays
            bounded. Gathers those slots' cache rows and pending tokens, runs
            one ``tfm.decode_step`` per row under ``vmap`` (each row advances
            at its own ``pos``), samples the next token with per-slot folded
            keys, and scatters rows and tokens back. Because duplicate lanes
            compute identical values, the duplicate scatter writes are
            idempotent. Idle and mid-prefill slots are never touched —
            half-prefilled requests stay out of the decode batch entirely."""
            sub_cache = jax.tree.map(lambda l: l[idx], cache)
            sub_tokens = tokens[idx]
            keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)

            def one(cache_row, token_row, k):
                logits, new_row = tfm.decode_step(params, cfg, cache_row,
                                                  token_row[None])
                return sample(logits[0], k, sampler_cfg), new_row

            nxt, new_sub = jax.vmap(one)(sub_cache, sub_tokens, keys)
            new_cache = jax.tree.map(lambda full, sub: full.at[idx].set(sub),
                                     cache, new_sub)
            return tokens.at[idx].set(nxt[:, None]), new_cache

        self._prefill_bucket = _prefill_bucket
        self._place = _place
        self._extend_chunk = _extend_chunk
        self._decode_active = _decode_active

    # -------------------------------------------------------------- protocol
    def attach(self, core: ServingCore) -> None:
        self.core = core
        if core.prefill_chunk_tokens is not None or core.prefix_caching:
            # both features run _extend_chunk at non-zero offsets (a cache
            # hit resumes prefill mid-prompt even with chunking off), so
            # both need exact attention-family continuation
            if self.cfg.family not in (DENSE, MOE, VLM) or self.cfg.is_encdec:
                raise ValueError(
                    f"chunked prefill / prefix caching needs an "
                    f"attention-family model (got {self.cfg.family}): "
                    f"recurrent families carry cross-chunk state "
                    f"forward_seq does not externalize")
            if self.cfg.sliding_window or self.prompt_len > self.cache_len:
                raise ValueError(
                    "chunked prefill / prefix caching needs an append-buffer "
                    "cache covering the whole prompt (prompt_len <= "
                    "cache_len, no sliding window): continuation chunks "
                    "write at absolute offsets")
        if (core.prefill_chunk_tokens is not None
                and core.prefill_chunk_tokens > self.cache_len):
            raise ValueError(
                f"prefill_chunk_tokens={core.prefill_chunk_tokens} "
                f"exceeds cache_len={self.cache_len}: a continuation "
                f"chunk must fit the cache lane it extends")
        if core.prefix_caching and not self.paged:
            # keep the device-side store in lockstep with the accounting:
            # when the allocator reclaims a cached block, its KV goes too
            # (paged mode needs no mirror — the pool block *is* the cache
            # entry, and the allocator's refcount/LRU governs it directly)
            core.allocator.add_evict_listener(
                lambda h: self._prefix_store.pop(h, None))
        if self.paged:
            if self.cfg.family not in (DENSE, MOE, VLM) or self.cfg.is_encdec:
                raise ValueError(
                    f"paged KV needs an attention-family model (got "
                    f"{self.cfg.family}): recurrent / cross-attention "
                    f"caches are not block-structured")
            if self.cfg.sliding_window:
                raise ValueError(
                    "paged KV uses full-length block tables; sliding-window "
                    "lanes are shorter than the position space they cover")
            alloc = core.allocator
            if alloc.total_blocks >= UNBOUNDED_BLOCKS:
                raise ValueError("paged KV needs a bounded allocator: the "
                                 "pool is materialized at total_blocks")
            if self.cache_len % alloc.block_size or \
                    self.cache_len < alloc.block_size:
                raise ValueError(
                    f"paged KV needs block_size | cache_len "
                    f"(got {alloc.block_size} and {self.cache_len})")
            self._build_paged(alloc)

    # ------------------------------------------------------------- paged pool
    def _build_paged(self, alloc: BlockAllocator) -> None:
        """Materialize the global KV pool and compile the paged programs.

        Layout: ``(total_blocks + 1, L, block_size, KH, dh)`` per pool —
        block-major so one table entry is one contiguous row. The serving
        truth lives here; per-dispatch the programs gather a request's
        table into a contiguous ``(L, 1, cache_len, KH, dh)`` lane, run the
        *same* ``forward_chunk`` / ``decode_step`` math as contiguous mode
        (rows at positions >= pos are masked to an exact constant, so
        gathered-garbage lanes produce bit-identical outputs), and scatter
        only the blocks the step wrote back into the pool."""
        cfg, sampler_cfg = self.cfg, self._sampler_cfg
        kshape = self.cache["k"].shape            # (max_batch, L, 1, W, KH, dh)
        L, _, W, KH, dh = kshape[1:]
        bs = alloc.block_size
        mb = W // bs
        n = alloc.total_blocks
        self._null_block = n
        self._lane_blocks = mb
        self.k_pool = jnp.zeros((n + 1, L, bs, KH, dh), self.cache["k"].dtype)
        self.v_pool = jnp.zeros((n + 1, L, bs, KH, dh), self.cache["v"].dtype)

        def lane(pool, table):
            """Gather one table into a contiguous cache lane
            (max_blocks,) → (L, 1, W, KH, dh)."""
            x = pool[table]                       # (mb, L, bs, KH, dh)
            return jnp.moveaxis(x, 1, 0).reshape(L, 1, W, KH, dh)

        @jax.jit
        def _place_paged(k_pool, v_pool, bucket_k, bucket_v, full_tokens,
                         nxt, tables, slot_ids):
            """Scatter a prefilled bucket's leading blocks into the pool.

            ``bucket_k/v``: (L, B, W, KH, dh) from ``_prefill_bucket``;
            ``tables``: (B, nb) physical destination of each sequence's
            first nb = ceil(bucket_len / bs) blocks (padding lanes replay
            lane 0, so duplicate writes are idempotent; null entries absorb
            unreserved rows)."""
            nb = tables.shape[1]

            def to_blocks(x):                     # → (B, nb, L, bs, KH, dh)
                xb = x[:, :, :nb * bs].reshape(L, x.shape[1], nb, bs, KH, dh)
                return jnp.moveaxis(xb, (0, 1, 2), (2, 0, 1))

            k_pool = k_pool.at[tables].set(to_blocks(bucket_k))
            v_pool = v_pool.at[tables].set(to_blocks(bucket_v))
            return k_pool, v_pool, full_tokens.at[slot_ids].set(nxt[:, None])

        @jax.jit
        def _extend_chunk_paged(params, k_pool, v_pool, full_tokens, tokens,
                                slot_ids, tables, starts, commit, key):
            """Continuation chunk over gathered lanes (paged twin of
            ``_extend_chunk``). Writes land at [start, start+C) in lane
            space; the touched blocks — at most ceil(C/bs)+1 of them — are
            sliced back out of the updated lane and scattered to their pool
            homes. Slice start and destination indices clamp identically,
            so a clamped window only re-writes unchanged blocks with their
            own content (bitwise no-op, shared-prefix safe)."""
            c = tokens.shape[1]
            nb_w = min(mb, -(-c // bs) + 1)
            keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(slot_ids)

            def one(table, toks, start, k):
                row = {"k": lane(k_pool, table), "v": lane(v_pool, table),
                       "pos": start}
                logits, new_row = tfm.forward_chunk(params, cfg,
                                                    toks[None], row)
                jc = jnp.clip(start // bs, 0, mb - nb_w)
                dest = jax.lax.dynamic_slice(table, (jc,), (nb_w,))
                dk = jax.lax.dynamic_slice(new_row["k"], (0, 0, jc * bs, 0, 0),
                                           (L, 1, nb_w * bs, KH, dh))
                dv = jax.lax.dynamic_slice(new_row["v"], (0, 0, jc * bs, 0, 0),
                                           (L, 1, nb_w * bs, KH, dh))
                return sample(logits[0, -1], k, sampler_cfg), dest, dk, dv

            nxt, dest, dk, dv = jax.vmap(one)(tables, tokens, starts, keys)

            def to_blocks(x):                     # → (B, nb_w, L, bs, KH, dh)
                xb = x[:, :, 0].reshape(x.shape[0], L, nb_w, bs, KH, dh)
                return jnp.moveaxis(xb, 2, 1)

            k_pool = k_pool.at[dest].set(to_blocks(dk))
            v_pool = v_pool.at[dest].set(to_blocks(dv))
            kept = jnp.where(commit[:, None], nxt[:, None],
                             full_tokens[slot_ids])
            return full_tokens.at[slot_ids].set(kept), k_pool, v_pool

        @jax.jit
        def _decode_paged(params, k_pool, v_pool, full_tokens, idx, tables,
                          poss, key):
            """One decode iteration over gathered lanes (paged twin of
            ``_decode_active``). ``poss`` is the host-tracked lane position
            per active slot; the single KV row the step writes lands in
            block ``table[(pos % W) // bs]`` — a wrap (pos >= W, caching
            off) overwrites the sequence's own oldest block, which is
            exactly the contiguous ring semantics."""
            sub_tokens = full_tokens[idx]
            keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)

            def one(table, token_row, pos, k):
                row = {"k": lane(k_pool, table), "v": lane(v_pool, table),
                       "pos": pos}
                logits, new_row = tfm.decode_step(params, cfg, row,
                                                  token_row[None])
                j = (pos % W) // bs
                dest = table[j]
                dk = jax.lax.dynamic_slice(new_row["k"], (0, 0, j * bs, 0, 0),
                                           (L, 1, bs, KH, dh))
                dv = jax.lax.dynamic_slice(new_row["v"], (0, 0, j * bs, 0, 0),
                                           (L, 1, bs, KH, dh))
                return sample(logits[0], k, sampler_cfg), dest, dk, dv

            nxt, dest, dk, dv = jax.vmap(one)(tables, sub_tokens, poss, keys)
            k_pool = k_pool.at[dest].set(dk[:, :, 0])
            v_pool = v_pool.at[dest].set(dv[:, :, 0])
            return full_tokens.at[idx].set(nxt[:, None]), k_pool, v_pool

        self._place_paged = _place_paged
        self._extend_chunk_paged = _extend_chunk_paged
        self._decode_paged = _decode_paged

    def _table(self, req: Request, n: int) -> List[int]:
        """First ``n`` entries of the request's block table, null-padded —
        the per-dispatch physical index row. Unreserved requests (direct
        backend calls in unit tests) get an all-null table: their KV lands
        in the trash block and reads of it are masked."""
        tbl = (self.core.allocator.block_table(req.req_id)[:n]
               if self.core is not None else [])
        return tbl + [self._null_block] * (n - len(tbl))

    def kv_demand(self, req: Request) -> int:
        return self.prompt_len + min(req.true_length, self.cache_len)

    def prefix_tokens(self, req: Request) -> List[int]:
        """Prefix-sharing stream = the encoded *real* prompt ids (bucket
        padding is excluded: pad KV depends on where padding starts, so only
        whole blocks of real tokens are content-addressable)."""
        return self._prompt_ids(req)

    def prefill_total(self, req: Request) -> int:
        """Prompt tokens this engine actually prefills for ``req``: its
        hash-tokenized prompt padded up to the power-of-two bucket (or to
        ``prompt_len`` when bucketing is off). Chunk planning, the
        decode-ready check, and the first-output-token position all use this
        padded length, so chunked runs process the exact token stream the
        unchunked bucket path does."""
        return self._bucket_len(len(self._prompt_ids(req)))

    def _prompt_ids(self, req: Request) -> List[int]:
        """Encode (and cache) a prompt's token ids, truncated to
        ``prompt_len``. Cached for the request's residency so per-chunk
        slicing doesn't re-tokenize; dropped on ``release``."""
        ids = self._ids.get(req.req_id)
        if ids is None:
            ids = [t % self.cfg.vocab_size
                   for t in self.tok.encode(req.prompt)[:self.prompt_len]]
            self._ids[req.req_id] = ids
        return ids

    def _bucket_len(self, n_tokens: int) -> int:
        """Power-of-two token bucket for an ``n_tokens``-long prompt, clamped
        to [min_bucket, prompt_len]. Bounds the set of compiled prefill
        shapes; unbucketed mode pads everything to ``prompt_len``."""
        if not self.bucketed:
            return self.prompt_len
        return min(self.prompt_len, _next_pow2(max(n_tokens, self.min_bucket)))

    def bucket_lens(self) -> List[int]:
        if not self.bucketed:
            return [self.prompt_len]
        lens, b = [], self.min_bucket
        while b < self.prompt_len:
            lens.append(b)
            b *= 2
        return lens + [self.prompt_len]

    def warmup(self) -> float:
        """Pre-compile the (bucket_len × batch-size) shape grid, vLLM-style,
        so steady-state admission never pays jit. When the core is chunking,
        also compiles the continuation program for every (chunk, batch)
        shape. Returns wall seconds."""
        t0 = time.perf_counter()
        key = jax.random.PRNGKey(0)
        sizes, b = [], 1
        while b < _next_pow2(self.max_batch):
            sizes.append(b)
            b *= 2
        sizes.append(_next_pow2(self.max_batch))
        chunk = self.core.prefill_chunk_tokens if self.core else None
        # with power-of-two buckets and a power-of-two chunk the planner
        # only emits continuation chunks of exactly the budget length
        # (partial takes are head-of-line-only and bucket totals are
        # multiples of the chunk), so {chunk} is the whole extend grid; a
        # prefix-cache hit additionally admits at any block-multiple offset,
        # so its first suffix may be bucket − k·block_size long — warm the
        # *shortest* of those (bounded: long shared prefix + short unique
        # tail is the common hit shape, and an unbounded bucket×offset grid
        # would be O(prompt_len/block) compilations). Longer odd suffixes
        # lazily compile their length once, like odd chunk remainders.
        buckets = set(self.bucket_lens())
        ext_lens = {chunk} if chunk else set()
        if self.core is not None and self.core.prefix_caching:
            bs = self.core.allocator.block_size
            suffixes = sorted(bl - c for bl in buckets
                              for c in range(bs, bl, bs))
            ext_lens.update(suffixes[:8])
        bs = self.core.allocator.block_size if self.paged else 0
        for bl in sorted(buckets | ext_lens):
            for bsz in sizes:
                tokens = jnp.zeros((bsz, bl), jnp.int32)
                slots = jnp.zeros((bsz,), jnp.int32)
                if bl in buckets:
                    nxt, cache = self._prefill_bucket(self.params, tokens,
                                                      slots, key)
                    if self.paged:
                        # null-block tables: the warm dispatches scribble on
                        # the trash block only
                        nb = -(-bl // bs)
                        self._place_paged(
                            self.k_pool, self.v_pool, cache["k"], cache["v"],
                            self.slot_tokens, nxt,
                            jnp.full((bsz, nb), self._null_block, jnp.int32),
                            slots)
                    else:
                        self._place(self.cache, cache, self.slot_tokens, nxt,
                                    slots)
                if bl in ext_lens:
                    if self.paged:
                        self._extend_chunk_paged(
                            self.params, self.k_pool, self.v_pool,
                            self.slot_tokens, tokens, slots,
                            jnp.full((bsz, self._lane_blocks),
                                     self._null_block, jnp.int32),
                            jnp.zeros((bsz,), jnp.int32),
                            jnp.zeros((bsz,), bool), key)
                    else:
                        self._extend_chunk(self.params, self.cache,
                                           self.slot_tokens, tokens, slots,
                                           jnp.zeros((bsz,), bool), key)
        if self.core is not None and self.core.prefix_caching and \
                not self.paged:
            # warm the prefix-install ops (fragment concat + lane scatters)
            # for every block-multiple offset. Scribbling on slot 0 is
            # harmless: a slot claim always rewrites [0, pos) before use and
            # attention never reads rows at positions >= pos — the same
            # masking that makes slot *reuse* safe without zeroing
            bs = self.core.allocator.block_size
            blk = self.cache["k"][0, :, 0, :bs]
            for c in range(bs, max(self.bucket_lens()), bs):
                k = jnp.concatenate([blk] * (c // bs), axis=1)
                self.cache["k"] = self.cache["k"].at[0, :, 0, :c].set(k)
                self.cache["v"] = self.cache["v"].at[0, :, 0, :c].set(k)
                self.cache["pos"] = self.cache["pos"].at[0].set(0)
        for bsz in sizes:
            if self.paged:
                out, _, _ = self._decode_paged(
                    self.params, self.k_pool, self.v_pool, self.slot_tokens,
                    jnp.zeros((bsz,), jnp.int32),
                    jnp.full((bsz, self._lane_blocks), self._null_block,
                             jnp.int32),
                    jnp.zeros((bsz,), jnp.int32), key)
            else:
                out, _ = self._decode_active(self.params, self.cache,
                                             self.slot_tokens,
                                             jnp.zeros((bsz,), jnp.int32), key)
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    def _now(self, fallback: float) -> float:
        return self.core.clock.now() if self.core is not None else fallback

    def _record(self, req: Request, token, now: float) -> None:
        if self.record_tokens:
            req.generated_tokens.append(int(token))
        if self.core is not None and self.core.record_token_times:
            req.token_times.append(now)

    def _tokens_snapshot(self) -> Optional[np.ndarray]:
        """One host copy of ``slot_tokens`` for ``_record``; None when
        neither recording flag is on (skip the device→host transfer)."""
        if self.record_tokens or (self.core is not None
                                  and self.core.record_token_times):
            return np.asarray(self.slot_tokens)
        return None

    # ------------------------------------------------------- prefix caching
    def _store_prefix(self, req: Request) -> None:
        """Copy the completed prompt's content-named per-block K/V slices
        out of its lane into the fragment store (skipping blocks already
        stored by an earlier identical prefix, and blocks the allocator
        isn't tracking — e.g. past the hit cap or with caching off)."""
        core = self.core
        if core is None or not core.prefix_caching:
            return
        bs = core.allocator.block_size
        slot = self._slot_of[req.req_id]
        for i, h in enumerate(prefix_chunk_hashes(self._prompt_ids(req), bs)):
            if h in self._prefix_store or not core.allocator.tracked(h):
                continue
            self._prefix_store[h] = {
                "k": self.cache["k"][slot, :, 0, i * bs:(i + 1) * bs],
                "v": self.cache["v"][slot, :, 0, i * bs:(i + 1) * bs]}

    def _install_prefix(self, slot: int, req: Request, n_tokens: int) -> None:
        """Seed a freshly claimed lane with a cached prefix: write the hit
        chain's fragments at positions [0, n_tokens) and set the lane's
        ``pos``, so prefill resumes at the cached offset. The blocks are
        refcount-pinned by this request's reservation, so every fragment is
        guaranteed present (commit-before-hit + the eviction listener)."""
        bs = self.core.allocator.block_size
        hashes = prefix_chunk_hashes(self._prompt_ids(req), bs)[:n_tokens // bs]
        frags = [self._prefix_store[h] for h in hashes]
        k = jnp.concatenate([f["k"] for f in frags], axis=1)
        v = jnp.concatenate([f["v"] for f in frags], axis=1)
        self.cache["k"] = self.cache["k"].at[slot, :, 0, :n_tokens].set(k)
        self.cache["v"] = self.cache["v"].at[slot, :, 0, :n_tokens].set(v)
        self.cache["pos"] = self.cache["pos"].at[slot].set(n_tokens)
        self.prefix_installs += 1
        self.prefix_tokens_copied += n_tokens

    def prefill(self, chunks: Sequence[PrefillChunk], now: float) -> float:
        """Execute one step's planned prefill chunks (see ``ServingCore``).

        First chunks (``start == 0``) claim a free slot and run the bucketed
        ``_prefill_bucket``/``_place`` path, grouped by chunk length — with
        chunking off every chunk is a whole padded prompt and this *is* the
        historical one-dispatch-per-bucket admission. A prefix-cache hit's
        first chunk arrives with ``start > 0`` and no slot: it claims one,
        seeds it from the fragment store (``_install_prefix``), and then
        runs as a continuation. Continuation chunks run ``_extend_chunk``
        grouped by length; requests at different offsets share a dispatch
        since the offset is per-lane data. A request whose chunk reaches
        ``prefill_total`` gets its first output token committed
        (tokens_done/TTFT bookkeeping preserved across preemption
        re-admission, matching SimBackend's recompute semantics) and its
        prefix blocks' KV copied into the fragment store."""
        if not chunks:
            return now
        t0 = time.perf_counter()
        first_groups: Dict[int, list] = {}
        ext_groups: Dict[int, list] = {}
        for req, start, end in chunks:
            if req.req_id not in self._slot_of:
                slot = self.slot_req.index(None)
                self.slot_req[slot] = req
                self._slot_of[req.req_id] = slot
                if self.paged and self.core is not None \
                        and self.core.prefix_caching \
                        and self.prefill_total(req) + req.true_length - 1 \
                        > self.cache_len:
                    raise ValueError(
                        f"paged KV with prefix caching cannot ring-wrap: "
                        f"request {req.req_id} needs "
                        f"{self.prefill_total(req) + req.true_length - 1} "
                        f"positions > cache_len={self.cache_len} (a wrap "
                        f"would overwrite potentially shared prefix blocks)")
                if start > 0:               # admission at a cached offset
                    if self.paged:
                        # zero-copy hit: the reservation already aliased the
                        # shared prefix blocks into this request's table, so
                        # the pool rows *are* its cache — no KV moves, the
                        # suffix chunk below just resumes at ``start``
                        self.prefix_installs += 1
                    else:
                        self._install_prefix(slot, req, start)
            if start == 0:
                first_groups.setdefault(end, []).append(req)
            else:
                ext_groups.setdefault(end - start, []).append((req, start, end))

        if self.bucketed:
            first_batches = sorted(first_groups.items())
        else:                          # sequential: one dispatch per request
            first_batches = [(ln, [r]) for ln, g in sorted(first_groups.items())
                             for r in g]
        for bucket_len, group in first_batches:
            b = _next_pow2(len(group))
            tokens = np.zeros((b, bucket_len), np.int32)
            slots = np.zeros((b,), np.int32)
            for j, req in enumerate(group):
                ids = self._prompt_ids(req)[:bucket_len]
                tokens[j, :len(ids)] = ids
                slots[j] = self._slot_of[req.req_id]
            tokens[len(group):] = tokens[0]     # padding lanes replay lane 0
            slots[len(group):] = slots[0]
            self._key, sub = jax.random.split(self._key)
            slots_j = jnp.asarray(slots)
            nxt, bucket_cache = self._prefill_bucket(
                self.params, jnp.asarray(tokens), slots_j, sub)
            if self.paged:
                bs = self.core.allocator.block_size
                nb = -(-bucket_len // bs)
                tables = np.full((b, nb), self._null_block, np.int32)
                for j, req in enumerate(group):
                    tables[j] = self._table(req, nb)
                tables[len(group):] = tables[0]
                self.k_pool, self.v_pool, self.slot_tokens = self._place_paged(
                    self.k_pool, self.v_pool, bucket_cache["k"],
                    bucket_cache["v"], self.slot_tokens, nxt,
                    jnp.asarray(tables), slots_j)
            else:
                self.cache, self.slot_tokens = self._place(
                    self.cache, bucket_cache, self.slot_tokens, nxt, slots_j)
            self.prefill_dispatches += 1

        for chunk_len, group in sorted(ext_groups.items()):
            b = _next_pow2(len(group))
            tokens = np.zeros((b, chunk_len), np.int32)
            slots = np.zeros((b,), np.int32)
            starts = np.zeros((b,), np.int32)
            commit = np.zeros((b,), bool)
            for j, (req, start, end) in enumerate(group):
                ids = self._prompt_ids(req)[start:end]
                tokens[j, :len(ids)] = ids      # tail past len(ids) = pad 0s
                slots[j] = self._slot_of[req.req_id]
                starts[j] = start
                commit[j] = end >= self.prefill_total(req)
            tokens[len(group):] = tokens[0]
            slots[len(group):] = slots[0]
            starts[len(group):] = starts[0]
            commit[len(group):] = commit[0]
            self._key, sub = jax.random.split(self._key)
            if self.paged:
                tables = np.full((b, self._lane_blocks), self._null_block,
                                 np.int32)
                for j, (req, _s, _e) in enumerate(group):
                    tables[j] = self._table(req, self._lane_blocks)
                tables[len(group):] = tables[0]
                self.slot_tokens, self.k_pool, self.v_pool = \
                    self._extend_chunk_paged(
                        self.params, self.k_pool, self.v_pool,
                        self.slot_tokens, jnp.asarray(tokens),
                        jnp.asarray(slots), jnp.asarray(tables),
                        jnp.asarray(starts), jnp.asarray(commit), sub)
            else:
                self.slot_tokens, self.cache = self._extend_chunk(
                    self.params, self.cache, self.slot_tokens,
                    jnp.asarray(tokens), jnp.asarray(slots),
                    jnp.asarray(commit), sub)
            self.extend_dispatches += 1

        jax.block_until_ready(self.slot_tokens)
        self.prefill_seconds += time.perf_counter() - t0
        now = self._now(now)
        toks = self._tokens_snapshot()
        for req, _start, end in chunks:
            if end < self.prefill_total(req):
                continue                        # still mid-prompt
            self.prefill_requests += 1
            if self.paged:
                # the pool blocks *are* the citable KV (the core commits
                # their hashes); start the host mirror of the lane pos
                self._pos[req.req_id] = self.prefill_total(req)
            else:
                self._store_prefix(req)         # prompt KV is now citable
            # recompute semantics on re-admission after preemption: decode
            # progress and TTFT are preserved, matching SimBackend
            if req.tokens_done == 0:
                req.tokens_done = 1             # prefill emits token 1
                if toks is not None:
                    self._record(req, toks[self._slot_of[req.req_id], 0], now)
            if req.first_token_time is None:
                req.first_token_time = now
        return now

    def decode(self, now: float) -> float:
        ready = (self.core.decode_ready if self.core is not None
                 else lambda r: True)
        active = [i for i, r in enumerate(self.slot_req)
                  if r is not None and ready(r)]
        if not active:
            return now
        idx = np.asarray(
            active + [active[0]] * (_next_pow2(len(active)) - len(active)),
            np.int32)
        self._key, sub = jax.random.split(self._key)
        if self.paged:
            tables = np.full((len(idx), self._lane_blocks), self._null_block,
                             np.int32)
            poss = np.zeros((len(idx),), np.int32)
            for j, i in enumerate(idx):
                req = self.slot_req[i]
                tables[j] = self._table(req, self._lane_blocks)
                poss[j] = self._pos[req.req_id]
            self.slot_tokens, self.k_pool, self.v_pool = self._decode_paged(
                self.params, self.k_pool, self.v_pool, self.slot_tokens,
                jnp.asarray(idx), jnp.asarray(tables), jnp.asarray(poss), sub)
        else:
            self.slot_tokens, self.cache = self._decode_active(
                self.params, self.cache, self.slot_tokens, jnp.asarray(idx),
                sub)
        jax.block_until_ready(self.slot_tokens)
        now = self._now(now)
        toks = self._tokens_snapshot()
        for i in active:
            self.slot_req[i].tokens_done += 1
            if self.paged:
                self._pos[self.slot_req[i].req_id] += 1
            if toks is not None:
                self._record(self.slot_req[i], toks[i, 0], now)
        return now

    def release(self, req: Request) -> None:
        self._ids.pop(req.req_id, None)
        self._pos.pop(req.req_id, None)
        slot = self._slot_of.pop(req.req_id, None)
        if slot is not None:
            self.slot_req[slot] = None


class Engine:
    """RealBackend + ServingCore wiring (the historical engine interface)."""

    def __init__(self, cfg: ModelConfig, params, scheduler: Scheduler, *,
                 cache_len: int = 512, prompt_len: int = 32,
                 tokenizer: Optional[HashTokenizer] = None,
                 allocator: Optional[BlockAllocator] = None,
                 sampler: SamplerConfig = SamplerConfig(), seed: int = 0,
                 bucketed: bool = True,
                 paged: Optional[bool] = None,
                 record_tokens: bool = False,
                 config: Optional[ServingConfig] = None,
                 **core_kw):
        if paged is None:
            # auto: block-structured KV exists exactly for attention-family
            # append caches; recurrent/enc-dec/sliding-window lanes keep the
            # historical contiguous path
            paged = (cfg.family in (DENSE, MOE, VLM) and not cfg.is_encdec
                     and not cfg.sliding_window)
        # core behaviour: config=ServingConfig(...) or loose keywords
        # (chunking, caching, reservation, re-ranking, deadlines, shedding)
        # — a blessed translation, no deprecation warning
        config = resolve_config(config, core_kw)
        s = scheduler.max_batch
        self.scheduler = scheduler
        self.backend = RealBackend(
            cfg, params, max_batch=s, cache_len=cache_len,
            prompt_len=prompt_len, tokenizer=tokenizer, sampler=sampler,
            seed=seed, bucketed=bucketed, record_tokens=record_tokens,
            paged=paged)
        self.allocator = allocator or BlockAllocator(
            total_blocks=s * (-(-cache_len // 16)), block_size=16)
        self.core = ServingCore(scheduler, self.backend,
                                allocator=self.allocator, config=config)

    # -------------------------------------------------------------------- api
    @property
    def finished(self) -> List[Request]:
        return self.core.finished

    def submit(self, requests: Sequence[Request]) -> None:
        self.core.submit(requests)

    def warmup(self) -> float:
        return self.backend.warmup()

    def run(self, *, time_scale: float = 1.0, log_every: float = 0.0,
            log_fn=print) -> List[Request]:
        """Serve everything submitted; returns finished requests.

        ``time_scale`` multiplies trace arrival times (replay a GPU-scale
        trace on CPU without idling)."""
        if time_scale != 1.0:
            for r in self.core._pending:
                r.arrival_time *= time_scale
        self.core.clock = WallClock()           # origin = serving start
        return self.core.run(log_every=log_every, log_fn=log_fn)


def serve(cfg: ModelConfig, params, requests: Sequence[Request], policy, *,
          max_batch: int = 8, cache_len: int = 256, prompt_len: int = 32,
          starvation_threshold: float = 120.0, time_scale: float = 1.0,
          log_every: float = 0.0, bucketed: bool = True,
          kv_blocks: Optional[int] = None,
          paged: Optional[bool] = None,
          config: Optional[ServingConfig] = None,
          **core_kw) -> LatencyReport:
    """Convenience wrapper: fresh engine + scheduler, serve, report. Core
    behaviour comes from ``config`` or loose keywords (chunking, caching,
    reservation mode, re-ranking, deadlines, shedding, …); dropped requests
    are counted in the report, never silently lost."""
    config = resolve_config(config, core_kw)
    sched = Scheduler(policy=policy, max_batch=max_batch,
                      starvation_threshold=starvation_threshold)
    allocator = BlockAllocator(kv_blocks, 16) if kv_blocks else None
    eng = Engine(cfg, params, sched, cache_len=cache_len,
                 prompt_len=prompt_len, allocator=allocator,
                 bucketed=bucketed, paged=paged, config=config)
    eng.submit(requests)
    finished = eng.run(time_scale=time_scale, log_every=log_every)
    dropped = eng.core.dropped
    assert len(finished) + len(dropped) == len(requests), \
        (len(finished), len(dropped), len(requests))
    return report(policy.name, finished,
                  counters=RunCounters(
                      reranks=(eng.core.rerank_count
                               if config.rerank_enabled else None),
                      dropped=tuple(dropped) if dropped else None))
