"""Declarative multi-tenant SLO workloads: the traces PARS gets judged on.

Every benchmark before this module hand-rolled its own trace (one Poisson
stream, one bimodal length mix) and reported means. Production schedulers are
judged on something harsher — *per-class SLO attainment and goodput under
bursty multi-tenant load* (SNIPPETS ch. 9; the evaluation setup of learned
re-ranking papers) — so this module generates exactly that, declaratively and
reproducibly:

* **bursty arrivals** — each tenant cycles through :class:`ArrivalPhase`
  segments (rate, duration): Poisson within a phase, on/off burst structure
  across phases. A tenant with ``(quiet, burst)`` phases hammers the queue
  periodically; a steady tenant is one phase.
* **multi-turn conversations** — an arrival starts a conversation; follow-up
  turns re-arrive after a think-time gap with a prompt that *extends* the
  previous turn's prompt (system prefix + accumulated turns + assistant
  echo). Chained block hashes (``prefix_chunk_hashes``) make each turn a
  natural prefix-cache hit on the committed blocks of the turn before it —
  the cache churn pattern real serving sees, not a synthetic duplicate
  stream. Tenants also share a per-tenant system prompt across
  conversations (cross-conversation sharing).
* **reasoning long-tail outputs** — :class:`OutputDist` is a lognormal body
  with an optional ``long_frac`` tail multiplier: most answers are short,
  a few think for thousands of tokens. The tail is what separates
  length-aware scheduling from FCFS.
* **priority classes carrying SLOs** — each conversation draws a
  :class:`PriorityClass` (weighted) whose :class:`SLO` targets (TTFT,
  mean inter-token gap) land on every request of the conversation as
  ``Request.slo_ttft_s`` / ``slo_itl_s``, with ``tenant`` /
  ``priority_class`` / ``priority`` alongside. ``metrics.slo_report``
  scores a run against them; the core's overload shedding reads
  ``priority`` to pick victims.

Determinism: the whole trace is a pure function of the :class:`WorkloadSpec`
(including its seed). Each tenant draws from ``default_rng([seed, tenant
index])``, so adding a tenant never perturbs another tenant's stream, and
regenerating with the same spec is bit-identical (pinned by tests). Replay
the same trace under different policies with
:func:`repro.serving.simulator.clone_requests`.

The prompt-token convention matches the rest of the repo: ``prompt_len`` =
1 (CLS) + word count, the unit both the simulator's cost model and the
prefix-sharing stream (``HashTokenizer`` word hashes) charge in.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.core.scheduler.request import Request


@dataclass(frozen=True)
class SLO:
    """Latency targets one priority class promises. ``None`` = no promise
    on that axis (attainment reports NaN, never a fake 100%)."""
    ttft_s: Optional[float] = None    # arrival → first token
    itl_s: Optional[float] = None     # mean inter-token gap


@dataclass(frozen=True)
class PriorityClass:
    """One request class inside a tenant: its SLO contract, its numeric
    priority (read by overload shedding — higher survives longer), and its
    share of the tenant's conversations (``weight``, normalised over the
    tenant's classes)."""
    name: str
    slo: SLO = SLO()
    priority: int = 0
    weight: float = 1.0


@dataclass(frozen=True)
class OutputDist:
    """Reasoning long-tail output lengths: a lognormal body (median
    ``median_tokens``, log-sigma ``sigma``) where each draw is stretched by
    ``long_scale`` with probability ``long_frac`` — the o1-style "thinks
    for pages" tail. Clamped to [min_tokens, max_tokens]."""
    median_tokens: int = 48
    sigma: float = 0.6
    long_frac: float = 0.0
    long_scale: float = 8.0
    min_tokens: int = 2
    max_tokens: int = 4096

    def __post_init__(self) -> None:
        if self.median_tokens < 1:
            raise ValueError("median_tokens must be >= 1")
        if not 0.0 <= self.long_frac <= 1.0:
            raise ValueError("long_frac must be in [0, 1]")
        if self.min_tokens < 1 or self.max_tokens < self.min_tokens:
            raise ValueError("need 1 <= min_tokens <= max_tokens")

    def sample(self, rng: np.random.Generator) -> int:
        n = self.median_tokens * float(rng.lognormal(0.0, self.sigma))
        if self.long_frac and rng.random() < self.long_frac:
            n *= self.long_scale
        return int(np.clip(round(n), self.min_tokens, self.max_tokens))


@dataclass(frozen=True)
class ArrivalPhase:
    """One segment of a tenant's on/off arrival cycle: Poisson at
    ``rate_per_s`` for ``duration_s`` seconds. ``rate_per_s=0`` is a quiet
    phase. Tenants cycle their phase tuple until the workload window ends."""
    rate_per_s: float
    duration_s: float

    def __post_init__(self) -> None:
        if self.rate_per_s < 0:
            raise ValueError("rate_per_s must be >= 0")
        if self.duration_s <= 0:
            raise ValueError("duration_s must be positive")


@dataclass(frozen=True)
class ConversationSpec:
    """Multi-turn structure: after each turn the conversation continues
    with probability ``p_continue`` (capped at ``max_turns``), re-arriving
    after an exponential think-time gap (mean ``think_time_s``). Each turn
    appends ``turn_words`` fresh user words plus an assistant echo of up to
    ``echo_cap_words`` words per generated token of the previous answer, on
    top of the tenant's ``system_words``-word shared system prompt."""
    max_turns: int = 1
    p_continue: float = 0.0
    think_time_s: float = 2.0
    turn_words: int = 12
    echo_cap_words: int = 48

    def __post_init__(self) -> None:
        if self.max_turns < 1:
            raise ValueError("max_turns must be >= 1")
        if not 0.0 <= self.p_continue <= 1.0:
            raise ValueError("p_continue must be in [0, 1]")
        if self.think_time_s < 0:
            raise ValueError("think_time_s must be >= 0")
        if self.turn_words < 1:
            raise ValueError("turn_words must be >= 1")


@dataclass(frozen=True)
class TenantSpec:
    """One tenant: its burst cycle, class mix, output distribution,
    conversation shape, and shared system-prompt length."""
    name: str
    phases: Tuple[ArrivalPhase, ...]
    classes: Tuple[PriorityClass, ...] = (PriorityClass("default"),)
    outputs: OutputDist = OutputDist()
    conversation: ConversationSpec = ConversationSpec()
    system_words: int = 32

    def __post_init__(self) -> None:
        if not self.phases:
            raise ValueError(f"tenant {self.name!r} needs >= 1 arrival phase")
        if not self.classes:
            raise ValueError(f"tenant {self.name!r} needs >= 1 class")
        if any(c.weight < 0 for c in self.classes) \
                or not any(c.weight > 0 for c in self.classes):
            raise ValueError(f"tenant {self.name!r} class weights must be "
                             f">= 0 with at least one > 0")
        if self.system_words < 0:
            raise ValueError("system_words must be >= 0")


@dataclass(frozen=True)
class WorkloadSpec:
    """The whole declarative workload: tenants + window + seed. The trace
    is a pure function of this record."""
    tenants: Tuple[TenantSpec, ...]
    duration_s: float = 60.0
    seed: int = 0

    def __post_init__(self) -> None:
        if not self.tenants:
            raise ValueError("need >= 1 tenant")
        if len({t.name for t in self.tenants}) != len(self.tenants):
            raise ValueError("tenant names must be unique")
        if self.duration_s <= 0:
            raise ValueError("duration_s must be positive")


def _conversation_starts(tenant: TenantSpec, duration_s: float,
                         rng: np.random.Generator) -> List[float]:
    """Poisson-within-phase arrival times over the cycled burst phases."""
    starts: List[float] = []
    t, i = 0.0, 0
    while t < duration_s:
        phase = tenant.phases[i % len(tenant.phases)]
        end = min(t + phase.duration_s, duration_s)
        if phase.rate_per_s > 0:
            tt = t + float(rng.exponential(1.0 / phase.rate_per_s))
            while tt < end:
                starts.append(tt)
                tt += float(rng.exponential(1.0 / phase.rate_per_s))
        t, i = end, i + 1
    return starts


def _pick_class(tenant: TenantSpec,
                rng: np.random.Generator) -> PriorityClass:
    w = np.asarray([c.weight for c in tenant.classes], dtype=float)
    return tenant.classes[int(rng.choice(len(w), p=w / w.sum()))]


def generate_trace(spec: WorkloadSpec) -> List[Request]:
    """The trace: requests sorted by arrival time, ``req_id`` = position.

    Each tenant's stream is drawn from ``default_rng([spec.seed, tenant
    index])`` — independent substreams, so tenants never perturb each
    other and the whole trace is reproducible from the spec alone.
    Conversation turns share a growing textual prefix (system prompt +
    prior turns + assistant echoes), which the prefix cache's chained
    block hashes turn into real hits; ``true_length`` draws from the
    tenant's long-tail output distribution; the conversation's priority
    class stamps tenant/class/priority/SLO annotations on every turn."""
    rows: List[Request] = []
    for ti, tenant in enumerate(spec.tenants):
        rng = np.random.default_rng([spec.seed, ti])
        conv = tenant.conversation
        system = " ".join(f"{tenant.name}s{k}"
                          for k in range(tenant.system_words))
        for ci, t0 in enumerate(_conversation_starts(tenant, spec.duration_s,
                                                     rng)):
            klass = _pick_class(tenant, rng)
            prompt, t = system, t0
            for turn in range(conv.max_turns):
                user = " ".join(f"{tenant.name}c{ci}t{turn}w{j}"
                                for j in range(conv.turn_words))
                prompt = (prompt + " " + user) if prompt else user
                out_len = tenant.outputs.sample(rng)
                n_words = len(prompt.split())
                r = Request(0, prompt, float(t), 1 + n_words, out_len,
                            tenant=tenant.name, priority_class=klass.name,
                            priority=klass.priority,
                            slo_ttft_s=klass.slo.ttft_s,
                            slo_itl_s=klass.slo.itl_s)
                rows.append(r)
                if (turn + 1 >= conv.max_turns
                        or rng.random() >= conv.p_continue):
                    break
                # next turn extends this prompt with the assistant's echo
                # (committed blocks of *this* turn become the next turn's
                # prefix hit) and re-arrives after think time + a service
                # proxy so a follow-up never precedes its own answer
                echo = " ".join(f"{tenant.name}c{ci}a{turn}e{j}"
                                for j in range(min(out_len,
                                                   conv.echo_cap_words)))
                prompt = prompt + " " + echo
                gap = (float(rng.exponential(conv.think_time_s))
                       if conv.think_time_s else 0.0)
                t += 0.02 * out_len + gap
    rows.sort(key=lambda r: (r.arrival_time, r.tenant))
    for i, r in enumerate(rows):
        r.req_id = i
    return rows


def trace_summary(reqs: List[Request]) -> dict:
    """Shape-of-the-trace dict for benchmark JSON output (counts per tenant
    and class, token totals) — enough to eyeball a regenerated trace."""
    per_tenant: dict = {}
    per_class: dict = {}
    for r in reqs:
        per_tenant[r.tenant] = per_tenant.get(r.tenant, 0) + 1
        per_class[r.priority_class] = per_class.get(r.priority_class, 0) + 1
    return dict(
        n_requests=len(reqs),
        prompt_tokens=int(sum(r.prompt_len for r in reqs)),
        output_tokens=int(sum(r.true_length for r in reqs)),
        span_s=(float(reqs[-1].arrival_time - reqs[0].arrival_time)
                if reqs else 0.0),
        per_tenant=per_tenant,
        per_class=per_class,
    )
