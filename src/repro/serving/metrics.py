"""Serving metrics: the paper's average & p90 *per-token* latency (§IV) plus
the two latency axes chunked prefill trades between:

* **TTFT** (arrival → first token): chunking a long prompt across steps
  delays *its* first token;
* **inter-token latency** (gap between consecutive output tokens of a
  request already decoding): chunking exists to protect exactly this — an
  unchunked long-prompt burst shows up as a p99 ITL spike on every
  co-resident request.

ITL percentiles come from actual per-token gaps when the run recorded
``Request.token_times`` (``record_token_times=True`` on the core), and fall
back to each request's mean gap (finish − first_token)/(n − 1) otherwise.

Multi-replica runs aggregate through :func:`router_report`: one pooled
``LatencyReport`` over every replica's finished requests plus per-replica
reports and router-level signals (load imbalance, cross-replica
prefix-hit rate, routed TTFT). Aggregation is NaN-safe for replicas that
served zero requests — empty replicas contribute all-NaN per-replica rows
and are excluded from imbalance means; they never poison the pooled
aggregate (which is computed from the pooled request list, not by
averaging per-replica summaries).

Optional run counters (rerank refreshes, dropped requests, scorer faults,
router crash/restart tallies) travel in one :class:`RunCounters` bundle —
``report(..., counters=RunCounters.from_core(core))`` — instead of a
per-feature kwarg each. The historical loose kwargs are still accepted for
one release and produce bit-identical reports (pinned by tests).

SLO-grade workloads (``repro.serving.workloads``) are scored by
:func:`slo_report`: per-priority-class TTFT/ITL SLO attainment, goodput
(= tokens of requests that met every applicable SLO, per second — the
SNIPPETS ch. 9 metric), and per-tenant tail percentiles, all under the same
NaN-when-absent convention (a class without an ITL SLO reports NaN ITL
attainment, never a fake 100%).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler.request import Request, RequestState


@dataclass(frozen=True)
class LatencyReport:
    policy: str
    n_requests: int
    avg_per_token_latency: float      # mean over requests of e2e/outlen
    p90_per_token_latency: float      # 90th percentile of the same
    avg_ttft: float                   # time to first token
    makespan: float                   # last finish − first arrival
    throughput_tok_s: float
    mean_wait: float                  # arrival → admission
    # TTFT tail and decode-gap percentiles (reported separately so prefill
    # policy changes that trade TTFT against inter-token latency are visible)
    p99_ttft: float = float("nan")
    p50_itl: float = float("nan")     # median inter-token gap
    p99_itl: float = float("nan")     # tail inter-token gap (HOL stalls)
    # Prefix caching (NaN when the run had caching disabled — a request only
    # carries ``cached_prefix_tokens`` once the core looked its prefix up)
    prefix_hit_rate: float = float("nan")       # share of requests with a hit
    prefill_tokens_saved: float = float("nan")  # prompt tokens not recomputed
    # Incremental KV reservation (NaN when the run reserved full demand at
    # admission — the counters only exist under kv_reservation="incremental")
    grow_failures: float = float("nan")         # decode-time grow denials
    grow_preemptions: float = float("nan")      # evictions those denials forced
    # Iterative re-ranking (NaN when the run ranked once at arrival — the
    # counters only exist when a rerank cadence was configured)
    reranks: float = float("nan")               # priority-key refreshes
    rerank_preemptions: float = float("nan")    # evictions in refreshed cycles
    # Fault tolerance (NaN when the run had no fault layer — no deadlines,
    # no shedding config, no fault schedule; pass ``dropped`` to ``report``
    # to activate them, even as an empty list → true zeros)
    dropped_total: float = float("nan")         # all terminal non-success exits
    deadline_cancelled: float = float("nan")    # CANCELLED (deadline expiry)
    shed: float = float("nan")                  # SHED (overload shedding)
    rejected: float = float("nan")              # REJECTED (KV-infeasible)
    failed: float = float("nan")                # FAILED (failover budget)
    failovers: float = float("nan")             # crash re-dispatches absorbed
    # Predictor degradation ladder (NaN unless the policy counters are passed)
    scorer_failures: float = float("nan")       # failed scorer dispatches
    predictor_degradations: float = float("nan")  # SJF → FCFS transitions
    predictor_recoveries: float = float("nan")    # FCFS → SJF recoveries

    def row(self) -> str:
        return (f"{self.policy:10s} n={self.n_requests:5d} "
                f"avg={self.avg_per_token_latency * 1e3:9.2f} ms/tok  "
                f"p90={self.p90_per_token_latency * 1e3:9.2f} ms/tok  "
                f"ttft={self.avg_ttft:7.2f} s  "
                f"p99_itl={self.p99_itl * 1e3:8.2f} ms  "
                f"tput={self.throughput_tok_s:9.1f} tok/s")


def _mean(a: np.ndarray) -> float:
    """NaN-safe mean: empty inputs (e.g. a run where no request records
    ``first_token_time``) yield NaN without the numpy empty-slice warning."""
    return float(a.mean()) if len(a) else float("nan")


def _pct(a: np.ndarray, q: float) -> float:
    return float(np.percentile(a, q)) if len(a) else float("nan")


def itl_samples(finished: Sequence[Request]) -> np.ndarray:
    """Inter-token-latency samples pooled across requests.

    Per request: consecutive gaps of ``token_times`` when recorded (the
    first token is TTFT, not ITL, so only gaps *between* output tokens
    count); otherwise the mean gap (finish − first_token)/(n − 1). Requests
    with fewer than two output tokens contribute nothing."""
    samples: List[float] = []
    for r in finished:
        if len(r.token_times) >= 2:
            samples.extend(np.diff(r.token_times))
        elif (r.true_length >= 2 and r.first_token_time is not None
              and r.finish_time is not None):
            samples.append((r.finish_time - r.first_token_time)
                           / (r.true_length - 1))
    return np.asarray(samples, dtype=float)


def _fault_fields(dropped: Optional[Sequence[Request]],
                  scorer_failures: Optional[int],
                  degradations: Optional[int],
                  recoveries: Optional[int]) -> dict:
    """Fault-tolerance counter fields for :class:`LatencyReport`. ``None``
    inputs report NaN (the run had no fault layer); a passed-but-empty
    ``dropped`` reports true zeros — "fault tolerance was on, nothing was
    dropped" is a result, not an absence."""
    out = {}
    if dropped is not None:
        by_reason = {}
        fos = 0.0
        for r in dropped:
            by_reason[r.drop_reason] = by_reason.get(r.drop_reason, 0) + 1
            fos += r.failovers or 0
        out.update(
            dropped_total=float(len(dropped)),
            deadline_cancelled=float(by_reason.get("deadline", 0)),
            shed=float(by_reason.get("overload", 0)),
            rejected=float(by_reason.get("kv-infeasible", 0)),
            failed=float(by_reason.get("failover-budget", 0)),
            failovers=fos,
        )
    if scorer_failures is not None:
        out["scorer_failures"] = float(scorer_failures)
    if degradations is not None:
        out["predictor_degradations"] = float(degradations)
    if recoveries is not None:
        out["predictor_recoveries"] = float(recoveries)
    return out


@dataclass(frozen=True)
class RunCounters:
    """Every optional counter a run can hand the report layer, in one
    bundle. Each field keeps the individual kwargs' convention: ``None``
    means "that layer was not active" and reports NaN; a real zero means
    "active, nothing happened" and reports 0.

    Collect with :meth:`from_core` (single-core runs: rerank cadence from
    the core's config, drops from ``core.dropped``, scorer fault ladder from
    the policy) or :meth:`from_router` (adds per-replica crash/restart
    tallies and failover re-dispatches), or construct directly when a
    benchmark owns its own counting.
    """
    reranks: Optional[float] = None            # priority-key refreshes
    dropped: Optional[Tuple[Request, ...]] = None  # terminal non-success
    scorer_failures: Optional[int] = None      # failed scorer dispatches
    degradations: Optional[int] = None         # SJF → FCFS transitions
    recoveries: Optional[int] = None           # FCFS → SJF recoveries
    # router-level (ignored by single-core ``report``)
    admit_attempts: Tuple[int, ...] = ()
    crashes: Optional[Tuple[int, ...]] = None  # per-replica crash counts
    restarts: Optional[Tuple[int, ...]] = None  # per-replica cold restarts
    redispatches: Optional[int] = None         # failover/escape re-routes

    @classmethod
    def from_core(cls, core) -> "RunCounters":
        """Counters of one ``ServingCore`` run. Reranks are reported iff a
        rerank cadence was configured; drops iff the core has a fault layer
        (deadlines or shedding configured, or anything actually dropped —
        an armed-but-quiet fault layer reports true zeros, not NaN); the
        scorer ladder iff the policy carries degradation state."""
        cfg = core.config
        faulty = (cfg.deadline_time_per_token is not None or cfg.shed_enabled
                  or bool(core.dropped))
        policy = core.scheduler.policy
        laddered = getattr(policy, "degradations", None) is not None
        return cls(
            reranks=core.rerank_count if cfg.rerank_enabled else None,
            dropped=tuple(core.dropped) if faulty else None,
            scorer_failures=(policy.scorer_failures
                             if hasattr(policy, "scorer_failures") and laddered
                             else None),
            degradations=policy.degradations if laddered else None,
            recoveries=policy.recoveries if laddered else None,
        )

    @classmethod
    def from_router(cls, router) -> "RunCounters":
        """Counters of one ``ReplicaRouter`` run (what
        ``ReplicaRouter.report`` always collected inline)."""
        reranked = any(c.config.rerank_enabled for c in router.replicas)
        faulty = bool(any(router.crash_count) or router._restart_at
                      or any(c.dropped for c in router.replicas)
                      or router.dropped)
        return cls(
            reranks=(sum(c.rerank_count for c in router.replicas)
                     if reranked else None),
            dropped=tuple(router.all_dropped) if faulty else None,
            admit_attempts=tuple(router.admit_attempts),
            crashes=tuple(router.crash_count) if faulty else None,
            restarts=tuple(router.restarts) if faulty else None,
            redispatches=router.redispatches if faulty else None,
        )


def _merge_counters(counters: Optional[RunCounters],
                    legacy: dict) -> RunCounters:
    """Resolve the one-release dual API: a :class:`RunCounters` bundle or
    the historical loose kwargs, never both."""
    passed = {k: v for k, v in legacy.items()
              if (v is not None and v != ()) }
    if counters is not None:
        if passed:
            raise TypeError(f"pass either counters=RunCounters(...) or "
                            f"legacy counter keywords, not both "
                            f"(got counters= and {sorted(passed)})")
        return counters
    if legacy.get("dropped") is not None:
        legacy["dropped"] = tuple(legacy["dropped"])
    return RunCounters(**legacy)


def report(policy: str, finished: Sequence[Request], *,
           counters: Optional[RunCounters] = None,
           reranks: Optional[float] = None,
           dropped: Optional[Sequence[Request]] = None,
           scorer_failures: Optional[int] = None,
           degradations: Optional[int] = None,
           recoveries: Optional[int] = None) -> LatencyReport:
    """``counters`` — a :class:`RunCounters` bundle holding every optional
    run counter (the blessed form; ``RunCounters.from_core(core)`` collects
    it). The loose keywords are the deprecated one-release equivalents and
    are mutually exclusive with ``counters``:

    ``reranks`` — core-level count of priority-key refreshes for the run
    that produced ``finished`` (``ServingCore.rerank_count``); ``None``
    (default) reports NaN, the "run never re-ranked" convention.
    ``dropped`` — terminally dropped requests (cancelled / shed / rejected /
    failed); latency stats are computed over ``finished`` only (a dropped
    request has no completion latency), the drop counters over ``dropped``.
    The scorer/degradation counters come from the policy's fault ladder
    (``Policy.scorer_failures`` etc.); ``None`` = no fault layer = NaN."""
    c = _merge_counters(counters, dict(
        reranks=reranks, dropped=dropped, scorer_failures=scorer_failures,
        degradations=degradations, recoveries=recoveries))
    reranks, dropped = c.reranks, c.dropped
    faults = _fault_fields(dropped, c.scorer_failures, c.degradations,
                           c.recoveries)
    if not finished:
        # every latency field NaN, including makespan/throughput: a replica
        # that served nothing has no makespan, and a literal 0.0 would skew
        # cross-replica min/mean comparisons the router report makes
        # (NaN means "absent" everywhere else in this report)
        return LatencyReport(policy=policy, n_requests=0,
                             avg_per_token_latency=float("nan"),
                             p90_per_token_latency=float("nan"),
                             avg_ttft=float("nan"), makespan=float("nan"),
                             throughput_tok_s=float("nan"),
                             mean_wait=float("nan"), **faults)
    per_tok = np.array([r.per_token_latency() for r in finished])
    ttft = np.array([(r.first_token_time - r.arrival_time) for r in finished
                     if r.first_token_time is not None])
    waits = np.array([(r.start_time - r.arrival_time) for r in finished
                      if r.start_time is not None])
    itl = itl_samples(finished)
    t0 = min(r.arrival_time for r in finished)
    t1 = max(r.finish_time for r in finished)
    tokens = sum(r.true_length for r in finished)
    cached = np.asarray([r.cached_prefix_tokens for r in finished
                         if r.cached_prefix_tokens is not None], dtype=float)
    growf = np.asarray([r.grow_failures for r in finished
                        if r.grow_failures is not None], dtype=float)
    growp = np.asarray([r.grow_preemptions for r in finished
                        if r.grow_preemptions is not None], dtype=float)
    rrank = np.asarray([r.rerank_preemptions for r in finished
                        if r.rerank_preemptions is not None], dtype=float)
    return LatencyReport(
        policy=policy,
        n_requests=len(finished),
        avg_per_token_latency=_mean(per_tok),
        p90_per_token_latency=float(np.percentile(per_tok, 90)),
        avg_ttft=_mean(ttft),
        makespan=float(t1 - t0),
        throughput_tok_s=float(tokens / max(t1 - t0, 1e-9)),
        mean_wait=_mean(waits),
        p99_ttft=_pct(ttft, 99),
        p50_itl=_pct(itl, 50),
        p99_itl=_pct(itl, 99),
        prefix_hit_rate=_mean(cached > 0),
        prefill_tokens_saved=float(cached.sum()) if len(cached)
        else float("nan"),
        grow_failures=float(growf.sum()) if len(growf) else float("nan"),
        grow_preemptions=float(growp.sum()) if len(growp) else float("nan"),
        reranks=float(reranks) if reranks is not None else float("nan"),
        rerank_preemptions=float(rrank.sum()) if len(rrank)
        else float("nan"),
        **faults,
    )


# --------------------------------------------------------------- multi-replica
@dataclass(frozen=True)
class RouterReport:
    """Aggregate + per-replica view of one multi-replica routed run.

    ``aggregate`` is a :class:`LatencyReport` over the *pooled* finished
    requests of every replica (so its means/percentiles are request-weighted,
    never averages of per-replica summaries — an empty replica cannot poison
    them with NaN). ``per_replica[i]`` is replica *i*'s own report; replicas
    that served nothing report all-NaN rows, by the same "NaN means absent"
    convention the latency report uses.
    """
    policy: str                            # routing policy name
    n_replicas: int
    n_requests: int                        # pooled finished count
    aggregate: LatencyReport
    per_replica: Tuple[LatencyReport, ...]
    requests_per_replica: Tuple[int, ...]
    tokens_per_replica: Tuple[int, ...]    # generated tokens per replica
    # max/mean served requests per *serving* replica (1.0 = perfectly even;
    # NaN when nothing finished anywhere). Replicas that served zero requests
    # still count in the mean — an idle replica IS imbalance.
    load_imbalance: float
    token_imbalance: float                 # same ratio over generated tokens
    # Prefix-cache affinity signal: pooled hit rate across replicas (NaN when
    # caching was off everywhere) — the number cache-affinity routing moves.
    cross_replica_hit_rate: float
    routed_ttft_mean_s: float              # arrival → first token, pooled
    routed_ttft_p99_s: float
    # Router-level admission-gate traffic per replica (attempts include
    # KV-gate deferrals re-tried on later cycles); () when the run did not
    # go through a router that counts them.
    admit_attempts: Tuple[int, ...] = ()
    # Fault tolerance (empty tuples / NaN when the run had no fault layer):
    # per-replica crash and cold-restart counts, and router-level failover /
    # escape re-dispatches. The pooled drop counters live on ``aggregate``.
    crashes: Tuple[int, ...] = ()
    restarts: Tuple[int, ...] = ()
    failover_redispatches: float = float("nan")

    def row(self) -> str:
        return (f"{self.policy:24s} n={self.n_requests:6d} "
                f"ttft={self.routed_ttft_mean_s * 1e3:9.2f} ms  "
                f"hit_rate={self.cross_replica_hit_rate:5.2f}  "
                f"imbalance={self.load_imbalance:5.2f}  "
                f"per_replica={list(self.requests_per_replica)}")


def _imbalance(counts: Sequence[int]) -> float:
    """max/mean of per-replica counts; NaN when every replica is empty (no
    load to be imbalanced about — 0/0 must not warn or crash)."""
    total = sum(counts)
    if not counts or total == 0:
        return float("nan")
    return max(counts) / (total / len(counts))


def router_report(policy: str,
                  per_replica_finished: Sequence[Sequence[Request]],
                  admit_attempts: Sequence[int] = (),
                  counters: Optional[RunCounters] = None,
                  reranks: Optional[float] = None,
                  dropped: Optional[Sequence[Request]] = None,
                  crashes: Optional[Sequence[int]] = None,
                  restarts: Optional[Sequence[int]] = None,
                  redispatches: Optional[int] = None) -> RouterReport:
    """NaN-safe aggregation of N replicas' finished requests (any of which
    may be empty) into one :class:`RouterReport`. ``counters`` — one
    :class:`RunCounters` bundle (``RunCounters.from_router(router)``
    collects it, ``admit_attempts`` included); the loose keywords are the
    deprecated one-release equivalents, mutually exclusive with it.
    ``reranks`` — total priority-key refreshes across replicas, ``None``
    when no replica re-ranked (reported NaN, like every other absent
    counter). The fault parameters (``dropped`` / ``crashes`` /
    ``restarts`` / ``redispatches``) follow the same convention: ``None`` =
    no fault layer = NaN/empty."""
    c = _merge_counters(counters, dict(
        reranks=reranks, dropped=dropped,
        admit_attempts=tuple(admit_attempts),
        crashes=tuple(crashes) if crashes is not None else None,
        restarts=tuple(restarts) if restarts is not None else None,
        redispatches=redispatches))
    pooled = [r for fin in per_replica_finished for r in fin]
    agg = report(policy, pooled,
                 counters=RunCounters(reranks=c.reranks, dropped=c.dropped))
    per = tuple(report(f"{policy}/r{i}", fin)
                for i, fin in enumerate(per_replica_finished))
    counts = tuple(len(fin) for fin in per_replica_finished)
    tokens = tuple(sum(r.true_length for r in fin)
                   for fin in per_replica_finished)
    return RouterReport(
        policy=policy,
        n_replicas=len(per_replica_finished),
        n_requests=len(pooled),
        aggregate=agg,
        per_replica=per,
        requests_per_replica=counts,
        tokens_per_replica=tokens,
        load_imbalance=_imbalance(counts),
        token_imbalance=_imbalance(tokens),
        cross_replica_hit_rate=agg.prefix_hit_rate,
        routed_ttft_mean_s=agg.avg_ttft,
        routed_ttft_p99_s=agg.p99_ttft,
        admit_attempts=tuple(c.admit_attempts),
        crashes=c.crashes if c.crashes is not None else (),
        restarts=c.restarts if c.restarts is not None else (),
        failover_redispatches=(float(c.redispatches)
                               if c.redispatches is not None
                               else float("nan")),
    )


# ------------------------------------------------------------------ SLO layer
def meets_ttft(r: Request) -> Optional[bool]:
    """Did ``r`` meet its TTFT SLO? ``None`` when it carries none (not
    applicable — never counted in attainment). A request that never produced
    a first token (dropped before decode) missed by definition."""
    if r.slo_ttft_s is None:
        return None
    if r.first_token_time is None:
        return False
    return (r.first_token_time - r.arrival_time) <= r.slo_ttft_s


def meets_itl(r: Request) -> Optional[bool]:
    """Did ``r`` meet its inter-token-latency SLO (mean gap between output
    tokens ≤ ``slo_itl_s``)? Gaps come from ``token_times`` when the run
    recorded them, else the (finish − first)/(n − 1) mean. ``None`` when the
    request carries no ITL SLO; a request with fewer than two output tokens
    has no inter-token gap and trivially meets; a dropped request missed."""
    if r.slo_itl_s is None:
        return None
    if r.state is not RequestState.FINISHED:
        return False
    if r.true_length < 2:
        return True
    if len(r.token_times) >= 2:
        mean_gap = float(np.mean(np.diff(r.token_times)))
    elif r.first_token_time is not None and r.finish_time is not None:
        mean_gap = (r.finish_time - r.first_token_time) / (r.true_length - 1)
    else:
        return False
    return mean_gap <= r.slo_itl_s


def meets_slo(r: Request) -> Optional[bool]:
    """Every *applicable* SLO met. ``None`` when the request carries no SLO
    at all — such requests are excluded from attainment rates but count
    toward goodput (nothing to violate)."""
    checks = [m for m in (meets_ttft(r), meets_itl(r)) if m is not None]
    if not checks:
        return None
    return all(checks)


def _attainment(flags: List[Optional[bool]]) -> float:
    """Share of applicable (non-``None``) flags that are True; NaN when no
    request in the group carried that SLO."""
    applicable = [f for f in flags if f is not None]
    return _mean(np.asarray(applicable, dtype=float)) if applicable \
        else float("nan")


@dataclass(frozen=True)
class ClassSLOStats:
    """One priority class's SLO scorecard (requests pooled across tenants)."""
    name: str
    priority: int
    n_requests: int                   # finished + dropped
    n_finished: int
    n_dropped: int
    ttft_attainment: float            # share meeting TTFT SLO (NaN: no SLO)
    itl_attainment: float             # share meeting ITL SLO (NaN: no SLO)
    slo_attainment: float             # share meeting every applicable SLO
    goodput_tok_s: float              # SLO-met output tokens / makespan
    throughput_tok_s: float           # all finished output tokens / makespan
    avg_ttft_s: float
    p99_ttft_s: float
    p99_itl_s: float

    def row(self) -> str:
        return (f"  {self.name:14s} n={self.n_requests:5d} "
                f"attain={self.slo_attainment:5.2f} "
                f"(ttft={self.ttft_attainment:5.2f} "
                f"itl={self.itl_attainment:5.2f})  "
                f"goodput={self.goodput_tok_s:8.1f} tok/s  "
                f"p99_ttft={self.p99_ttft_s:7.2f} s")


@dataclass(frozen=True)
class TenantStats:
    """One tenant's tail-latency row (finished requests only)."""
    name: str
    n_requests: int
    p50_ttft_s: float
    p99_ttft_s: float
    p99_per_token_latency: float
    slo_attainment: float


@dataclass(frozen=True)
class SLOReport:
    """Per-class SLO attainment + goodput for one run, aggregated alongside
    a :class:`LatencyReport` (the harness emits both). Goodput is the
    SNIPPETS ch. 9 metric: output tokens of requests that met *every*
    applicable SLO, per second of makespan — a scheduler that finishes many
    requests late scores high throughput and low goodput. Dropped requests
    (shed / cancelled / rejected / failed) count as SLO misses in every
    attainment rate and contribute zero goodput."""
    policy: str
    n_requests: int                   # finished + dropped
    n_finished: int
    n_dropped: int
    makespan_s: float                 # last finish − first arrival
    goodput_tok_s: float
    throughput_tok_s: float
    slo_attainment: float             # over requests carrying ≥ 1 SLO
    ttft_attainment: float
    itl_attainment: float
    per_class: Tuple[ClassSLOStats, ...] = ()
    per_tenant: Tuple[TenantStats, ...] = ()

    def rows(self) -> str:
        head = (f"{self.policy:12s} n={self.n_requests:5d} "
                f"attain={self.slo_attainment:5.2f}  "
                f"goodput={self.goodput_tok_s:8.1f} tok/s  "
                f"tput={self.throughput_tok_s:8.1f} tok/s")
        return "\n".join([head] + [c.row() for c in self.per_class])

    def cls(self, name: str) -> ClassSLOStats:
        """Lookup one class row by name (KeyError when absent)."""
        for c in self.per_class:
            if c.name == name:
                return c
        raise KeyError(name)


def _tenant_stats(name: str, reqs: List[Request]) -> TenantStats:
    fin = [r for r in reqs if r.state is RequestState.FINISHED]
    ttft = np.asarray([r.first_token_time - r.arrival_time for r in fin
                       if r.first_token_time is not None], dtype=float)
    per_tok = np.asarray([r.per_token_latency() for r in fin], dtype=float)
    return TenantStats(
        name=name, n_requests=len(reqs),
        p50_ttft_s=_pct(ttft, 50), p99_ttft_s=_pct(ttft, 99),
        p99_per_token_latency=_pct(per_tok, 99),
        slo_attainment=_attainment([meets_slo(r) for r in reqs]),
    )


def slo_report(policy: str, finished: Sequence[Request],
               dropped: Sequence[Request] = ()) -> SLOReport:
    """Score one run against the per-request SLO annotations
    (``slo_ttft_s`` / ``slo_itl_s`` / ``priority_class`` / ``tenant`` —
    see :mod:`repro.serving.workloads`). Requests without annotations are
    fine: they land in class ``"-"`` with NaN attainment and their tokens
    count toward both throughput and goodput (no SLO to violate)."""
    finished = list(finished)
    dropped = list(dropped)
    everything = finished + dropped
    if not everything:
        nan = float("nan")
        return SLOReport(policy=policy, n_requests=0, n_finished=0,
                         n_dropped=0, makespan_s=nan, goodput_tok_s=nan,
                         throughput_tok_s=nan, slo_attainment=nan,
                         ttft_attainment=nan, itl_attainment=nan)
    if finished:
        t0 = min(r.arrival_time for r in everything)
        t1 = max(r.finish_time for r in finished)
        makespan = max(t1 - t0, 1e-9)
    else:
        makespan = float("nan")

    def _goodput(reqs: List[Request]) -> float:
        good = sum(r.true_length for r in reqs
                   if r.state is RequestState.FINISHED
                   and meets_slo(r) is not False)
        return good / makespan

    def _throughput(reqs: List[Request]) -> float:
        return sum(r.true_length for r in reqs
                   if r.state is RequestState.FINISHED) / makespan

    by_class: Dict[str, List[Request]] = {}
    by_tenant: Dict[str, List[Request]] = {}
    for r in everything:
        by_class.setdefault(r.priority_class or "-", []).append(r)
        by_tenant.setdefault(r.tenant or "-", []).append(r)

    classes = []
    for name in sorted(by_class):
        reqs = by_class[name]
        fin = [r for r in reqs if r.state is RequestState.FINISHED]
        ttft = np.asarray([r.first_token_time - r.arrival_time for r in fin
                           if r.first_token_time is not None], dtype=float)
        itl = itl_samples(fin)
        classes.append(ClassSLOStats(
            name=name,
            priority=max((r.priority for r in reqs), default=0),
            n_requests=len(reqs), n_finished=len(fin),
            n_dropped=len(reqs) - len(fin),
            ttft_attainment=_attainment([meets_ttft(r) for r in reqs]),
            itl_attainment=_attainment([meets_itl(r) for r in reqs]),
            slo_attainment=_attainment([meets_slo(r) for r in reqs]),
            goodput_tok_s=_goodput(reqs),
            throughput_tok_s=_throughput(reqs),
            avg_ttft_s=_mean(ttft),
            p99_ttft_s=_pct(ttft, 99),
            p99_itl_s=_pct(itl, 99),
        ))

    return SLOReport(
        policy=policy,
        n_requests=len(everything),
        n_finished=len(finished),
        n_dropped=len(dropped),
        makespan_s=makespan,
        goodput_tok_s=_goodput(everything),
        throughput_tok_s=_throughput(everything),
        slo_attainment=_attainment([meets_slo(r) for r in everything]),
        ttft_attainment=_attainment([meets_ttft(r) for r in everything]),
        itl_attainment=_attainment([meets_itl(r) for r in everything]),
        per_class=tuple(classes),
        per_tenant=tuple(_tenant_stats(n, by_tenant[n])
                         for n in sorted(by_tenant)),
    )
