"""Serving metrics: the paper's average & p90 *per-token* latency (§IV) plus
the two latency axes chunked prefill trades between:

* **TTFT** (arrival → first token): chunking a long prompt across steps
  delays *its* first token;
* **inter-token latency** (gap between consecutive output tokens of a
  request already decoding): chunking exists to protect exactly this — an
  unchunked long-prompt burst shows up as a p99 ITL spike on every
  co-resident request.

ITL percentiles come from actual per-token gaps when the run recorded
``Request.token_times`` (``record_token_times=True`` on the core), and fall
back to each request's mean gap (finish − first_token)/(n − 1) otherwise.

Multi-replica runs aggregate through :func:`router_report`: one pooled
``LatencyReport`` over every replica's finished requests plus per-replica
reports and router-level signals (load imbalance, cross-replica
prefix-hit rate, routed TTFT). Aggregation is NaN-safe for replicas that
served zero requests — empty replicas contribute all-NaN per-replica rows
and are excluded from imbalance means; they never poison the pooled
aggregate (which is computed from the pooled request list, not by
averaging per-replica summaries).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler.request import Request


@dataclass(frozen=True)
class LatencyReport:
    policy: str
    n_requests: int
    avg_per_token_latency: float      # mean over requests of e2e/outlen
    p90_per_token_latency: float      # 90th percentile of the same
    avg_ttft: float                   # time to first token
    makespan: float                   # last finish − first arrival
    throughput_tok_s: float
    mean_wait: float                  # arrival → admission
    # TTFT tail and decode-gap percentiles (reported separately so prefill
    # policy changes that trade TTFT against inter-token latency are visible)
    p99_ttft: float = float("nan")
    p50_itl: float = float("nan")     # median inter-token gap
    p99_itl: float = float("nan")     # tail inter-token gap (HOL stalls)
    # Prefix caching (NaN when the run had caching disabled — a request only
    # carries ``cached_prefix_tokens`` once the core looked its prefix up)
    prefix_hit_rate: float = float("nan")       # share of requests with a hit
    prefill_tokens_saved: float = float("nan")  # prompt tokens not recomputed
    # Incremental KV reservation (NaN when the run reserved full demand at
    # admission — the counters only exist under kv_reservation="incremental")
    grow_failures: float = float("nan")         # decode-time grow denials
    grow_preemptions: float = float("nan")      # evictions those denials forced
    # Iterative re-ranking (NaN when the run ranked once at arrival — the
    # counters only exist when a rerank cadence was configured)
    reranks: float = float("nan")               # priority-key refreshes
    rerank_preemptions: float = float("nan")    # evictions in refreshed cycles
    # Fault tolerance (NaN when the run had no fault layer — no deadlines,
    # no shedding config, no fault schedule; pass ``dropped`` to ``report``
    # to activate them, even as an empty list → true zeros)
    dropped_total: float = float("nan")         # all terminal non-success exits
    deadline_cancelled: float = float("nan")    # CANCELLED (deadline expiry)
    shed: float = float("nan")                  # SHED (overload shedding)
    rejected: float = float("nan")              # REJECTED (KV-infeasible)
    failed: float = float("nan")                # FAILED (failover budget)
    failovers: float = float("nan")             # crash re-dispatches absorbed
    # Predictor degradation ladder (NaN unless the policy counters are passed)
    scorer_failures: float = float("nan")       # failed scorer dispatches
    predictor_degradations: float = float("nan")  # SJF → FCFS transitions
    predictor_recoveries: float = float("nan")    # FCFS → SJF recoveries

    def row(self) -> str:
        return (f"{self.policy:10s} n={self.n_requests:5d} "
                f"avg={self.avg_per_token_latency * 1e3:9.2f} ms/tok  "
                f"p90={self.p90_per_token_latency * 1e3:9.2f} ms/tok  "
                f"ttft={self.avg_ttft:7.2f} s  "
                f"p99_itl={self.p99_itl * 1e3:8.2f} ms  "
                f"tput={self.throughput_tok_s:9.1f} tok/s")


def _mean(a: np.ndarray) -> float:
    """NaN-safe mean: empty inputs (e.g. a run where no request records
    ``first_token_time``) yield NaN without the numpy empty-slice warning."""
    return float(a.mean()) if len(a) else float("nan")


def _pct(a: np.ndarray, q: float) -> float:
    return float(np.percentile(a, q)) if len(a) else float("nan")


def itl_samples(finished: Sequence[Request]) -> np.ndarray:
    """Inter-token-latency samples pooled across requests.

    Per request: consecutive gaps of ``token_times`` when recorded (the
    first token is TTFT, not ITL, so only gaps *between* output tokens
    count); otherwise the mean gap (finish − first_token)/(n − 1). Requests
    with fewer than two output tokens contribute nothing."""
    samples: List[float] = []
    for r in finished:
        if len(r.token_times) >= 2:
            samples.extend(np.diff(r.token_times))
        elif (r.true_length >= 2 and r.first_token_time is not None
              and r.finish_time is not None):
            samples.append((r.finish_time - r.first_token_time)
                           / (r.true_length - 1))
    return np.asarray(samples, dtype=float)


def _fault_fields(dropped: Optional[Sequence[Request]],
                  scorer_failures: Optional[int],
                  degradations: Optional[int],
                  recoveries: Optional[int]) -> dict:
    """Fault-tolerance counter fields for :class:`LatencyReport`. ``None``
    inputs report NaN (the run had no fault layer); a passed-but-empty
    ``dropped`` reports true zeros — "fault tolerance was on, nothing was
    dropped" is a result, not an absence."""
    out = {}
    if dropped is not None:
        by_reason = {}
        fos = 0.0
        for r in dropped:
            by_reason[r.drop_reason] = by_reason.get(r.drop_reason, 0) + 1
            fos += r.failovers or 0
        out.update(
            dropped_total=float(len(dropped)),
            deadline_cancelled=float(by_reason.get("deadline", 0)),
            shed=float(by_reason.get("overload", 0)),
            rejected=float(by_reason.get("kv-infeasible", 0)),
            failed=float(by_reason.get("failover-budget", 0)),
            failovers=fos,
        )
    if scorer_failures is not None:
        out["scorer_failures"] = float(scorer_failures)
    if degradations is not None:
        out["predictor_degradations"] = float(degradations)
    if recoveries is not None:
        out["predictor_recoveries"] = float(recoveries)
    return out


def report(policy: str, finished: Sequence[Request], *,
           reranks: Optional[float] = None,
           dropped: Optional[Sequence[Request]] = None,
           scorer_failures: Optional[int] = None,
           degradations: Optional[int] = None,
           recoveries: Optional[int] = None) -> LatencyReport:
    """``reranks`` — core-level count of priority-key refreshes for the run
    that produced ``finished`` (``ServingCore.rerank_count``); ``None``
    (default) reports NaN, the "run never re-ranked" convention.
    ``dropped`` — terminally dropped requests (cancelled / shed / rejected /
    failed); latency stats are computed over ``finished`` only (a dropped
    request has no completion latency), the drop counters over ``dropped``.
    The scorer/degradation counters come from the policy's fault ladder
    (``Policy.scorer_failures`` etc.); ``None`` = no fault layer = NaN."""
    faults = _fault_fields(dropped, scorer_failures, degradations, recoveries)
    if not finished:
        # every latency field NaN, including makespan/throughput: a replica
        # that served nothing has no makespan, and a literal 0.0 would skew
        # cross-replica min/mean comparisons the router report makes
        # (NaN means "absent" everywhere else in this report)
        return LatencyReport(policy=policy, n_requests=0,
                             avg_per_token_latency=float("nan"),
                             p90_per_token_latency=float("nan"),
                             avg_ttft=float("nan"), makespan=float("nan"),
                             throughput_tok_s=float("nan"),
                             mean_wait=float("nan"), **faults)
    per_tok = np.array([r.per_token_latency() for r in finished])
    ttft = np.array([(r.first_token_time - r.arrival_time) for r in finished
                     if r.first_token_time is not None])
    waits = np.array([(r.start_time - r.arrival_time) for r in finished
                      if r.start_time is not None])
    itl = itl_samples(finished)
    t0 = min(r.arrival_time for r in finished)
    t1 = max(r.finish_time for r in finished)
    tokens = sum(r.true_length for r in finished)
    cached = np.asarray([r.cached_prefix_tokens for r in finished
                         if r.cached_prefix_tokens is not None], dtype=float)
    growf = np.asarray([r.grow_failures for r in finished
                        if r.grow_failures is not None], dtype=float)
    growp = np.asarray([r.grow_preemptions for r in finished
                        if r.grow_preemptions is not None], dtype=float)
    rrank = np.asarray([r.rerank_preemptions for r in finished
                        if r.rerank_preemptions is not None], dtype=float)
    return LatencyReport(
        policy=policy,
        n_requests=len(finished),
        avg_per_token_latency=_mean(per_tok),
        p90_per_token_latency=float(np.percentile(per_tok, 90)),
        avg_ttft=_mean(ttft),
        makespan=float(t1 - t0),
        throughput_tok_s=float(tokens / max(t1 - t0, 1e-9)),
        mean_wait=_mean(waits),
        p99_ttft=_pct(ttft, 99),
        p50_itl=_pct(itl, 50),
        p99_itl=_pct(itl, 99),
        prefix_hit_rate=_mean(cached > 0),
        prefill_tokens_saved=float(cached.sum()) if len(cached)
        else float("nan"),
        grow_failures=float(growf.sum()) if len(growf) else float("nan"),
        grow_preemptions=float(growp.sum()) if len(growp) else float("nan"),
        reranks=float(reranks) if reranks is not None else float("nan"),
        rerank_preemptions=float(rrank.sum()) if len(rrank)
        else float("nan"),
        **faults,
    )


# --------------------------------------------------------------- multi-replica
@dataclass(frozen=True)
class RouterReport:
    """Aggregate + per-replica view of one multi-replica routed run.

    ``aggregate`` is a :class:`LatencyReport` over the *pooled* finished
    requests of every replica (so its means/percentiles are request-weighted,
    never averages of per-replica summaries — an empty replica cannot poison
    them with NaN). ``per_replica[i]`` is replica *i*'s own report; replicas
    that served nothing report all-NaN rows, by the same "NaN means absent"
    convention the latency report uses.
    """
    policy: str                            # routing policy name
    n_replicas: int
    n_requests: int                        # pooled finished count
    aggregate: LatencyReport
    per_replica: Tuple[LatencyReport, ...]
    requests_per_replica: Tuple[int, ...]
    tokens_per_replica: Tuple[int, ...]    # generated tokens per replica
    # max/mean served requests per *serving* replica (1.0 = perfectly even;
    # NaN when nothing finished anywhere). Replicas that served zero requests
    # still count in the mean — an idle replica IS imbalance.
    load_imbalance: float
    token_imbalance: float                 # same ratio over generated tokens
    # Prefix-cache affinity signal: pooled hit rate across replicas (NaN when
    # caching was off everywhere) — the number cache-affinity routing moves.
    cross_replica_hit_rate: float
    routed_ttft_mean_s: float              # arrival → first token, pooled
    routed_ttft_p99_s: float
    # Router-level admission-gate traffic per replica (attempts include
    # KV-gate deferrals re-tried on later cycles); () when the run did not
    # go through a router that counts them.
    admit_attempts: Tuple[int, ...] = ()
    # Fault tolerance (empty tuples / NaN when the run had no fault layer):
    # per-replica crash and cold-restart counts, and router-level failover /
    # escape re-dispatches. The pooled drop counters live on ``aggregate``.
    crashes: Tuple[int, ...] = ()
    restarts: Tuple[int, ...] = ()
    failover_redispatches: float = float("nan")

    def row(self) -> str:
        return (f"{self.policy:24s} n={self.n_requests:6d} "
                f"ttft={self.routed_ttft_mean_s * 1e3:9.2f} ms  "
                f"hit_rate={self.cross_replica_hit_rate:5.2f}  "
                f"imbalance={self.load_imbalance:5.2f}  "
                f"per_replica={list(self.requests_per_replica)}")


def _imbalance(counts: Sequence[int]) -> float:
    """max/mean of per-replica counts; NaN when every replica is empty (no
    load to be imbalanced about — 0/0 must not warn or crash)."""
    total = sum(counts)
    if not counts or total == 0:
        return float("nan")
    return max(counts) / (total / len(counts))


def router_report(policy: str,
                  per_replica_finished: Sequence[Sequence[Request]],
                  admit_attempts: Sequence[int] = (),
                  reranks: Optional[float] = None,
                  dropped: Optional[Sequence[Request]] = None,
                  crashes: Optional[Sequence[int]] = None,
                  restarts: Optional[Sequence[int]] = None,
                  redispatches: Optional[int] = None) -> RouterReport:
    """NaN-safe aggregation of N replicas' finished requests (any of which
    may be empty) into one :class:`RouterReport`. ``reranks`` — total
    priority-key refreshes across replicas, ``None`` when no replica
    re-ranked (reported NaN, like every other absent counter). The fault
    parameters (``dropped`` / ``crashes`` / ``restarts`` /
    ``redispatches``) follow the same convention: ``None`` = no fault
    layer = NaN/empty."""
    pooled = [r for fin in per_replica_finished for r in fin]
    agg = report(policy, pooled, reranks=reranks, dropped=dropped)
    per = tuple(report(f"{policy}/r{i}", fin)
                for i, fin in enumerate(per_replica_finished))
    counts = tuple(len(fin) for fin in per_replica_finished)
    tokens = tuple(sum(r.true_length for r in fin)
                   for fin in per_replica_finished)
    return RouterReport(
        policy=policy,
        n_replicas=len(per_replica_finished),
        n_requests=len(pooled),
        aggregate=agg,
        per_replica=per,
        requests_per_replica=counts,
        tokens_per_replica=tokens,
        load_imbalance=_imbalance(counts),
        token_imbalance=_imbalance(tokens),
        cross_replica_hit_rate=agg.prefix_hit_rate,
        routed_ttft_mean_s=agg.avg_ttft,
        routed_ttft_p99_s=agg.p99_ttft,
        admit_attempts=tuple(admit_attempts),
        crashes=tuple(crashes) if crashes is not None else (),
        restarts=tuple(restarts) if restarts is not None else (),
        failover_redispatches=(float(redispatches)
                               if redispatches is not None else float("nan")),
    )
