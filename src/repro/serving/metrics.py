"""Serving metrics: the paper's average & p90 *per-token* latency (§IV) plus
the two latency axes chunked prefill trades between:

* **TTFT** (arrival → first token): chunking a long prompt across steps
  delays *its* first token;
* **inter-token latency** (gap between consecutive output tokens of a
  request already decoding): chunking exists to protect exactly this — an
  unchunked long-prompt burst shows up as a p99 ITL spike on every
  co-resident request.

ITL percentiles come from actual per-token gaps when the run recorded
``Request.token_times`` (``record_token_times=True`` on the core), and fall
back to each request's mean gap (finish − first_token)/(n − 1) otherwise.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.core.scheduler.request import Request


@dataclass(frozen=True)
class LatencyReport:
    policy: str
    n_requests: int
    avg_per_token_latency: float      # mean over requests of e2e/outlen
    p90_per_token_latency: float      # 90th percentile of the same
    avg_ttft: float                   # time to first token
    makespan: float                   # last finish − first arrival
    throughput_tok_s: float
    mean_wait: float                  # arrival → admission
    # TTFT tail and decode-gap percentiles (reported separately so prefill
    # policy changes that trade TTFT against inter-token latency are visible)
    p99_ttft: float = float("nan")
    p50_itl: float = float("nan")     # median inter-token gap
    p99_itl: float = float("nan")     # tail inter-token gap (HOL stalls)
    # Prefix caching (NaN when the run had caching disabled — a request only
    # carries ``cached_prefix_tokens`` once the core looked its prefix up)
    prefix_hit_rate: float = float("nan")       # share of requests with a hit
    prefill_tokens_saved: float = float("nan")  # prompt tokens not recomputed
    # Incremental KV reservation (NaN when the run reserved full demand at
    # admission — the counters only exist under kv_reservation="incremental")
    grow_failures: float = float("nan")         # decode-time grow denials
    grow_preemptions: float = float("nan")      # evictions those denials forced

    def row(self) -> str:
        return (f"{self.policy:10s} n={self.n_requests:5d} "
                f"avg={self.avg_per_token_latency * 1e3:9.2f} ms/tok  "
                f"p90={self.p90_per_token_latency * 1e3:9.2f} ms/tok  "
                f"ttft={self.avg_ttft:7.2f} s  "
                f"p99_itl={self.p99_itl * 1e3:8.2f} ms  "
                f"tput={self.throughput_tok_s:9.1f} tok/s")


def _mean(a: np.ndarray) -> float:
    """NaN-safe mean: empty inputs (e.g. a run where no request records
    ``first_token_time``) yield NaN without the numpy empty-slice warning."""
    return float(a.mean()) if len(a) else float("nan")


def _pct(a: np.ndarray, q: float) -> float:
    return float(np.percentile(a, q)) if len(a) else float("nan")


def itl_samples(finished: Sequence[Request]) -> np.ndarray:
    """Inter-token-latency samples pooled across requests.

    Per request: consecutive gaps of ``token_times`` when recorded (the
    first token is TTFT, not ITL, so only gaps *between* output tokens
    count); otherwise the mean gap (finish − first_token)/(n − 1). Requests
    with fewer than two output tokens contribute nothing."""
    samples: List[float] = []
    for r in finished:
        if len(r.token_times) >= 2:
            samples.extend(np.diff(r.token_times))
        elif (r.true_length >= 2 and r.first_token_time is not None
              and r.finish_time is not None):
            samples.append((r.finish_time - r.first_token_time)
                           / (r.true_length - 1))
    return np.asarray(samples, dtype=float)


def report(policy: str, finished: Sequence[Request]) -> LatencyReport:
    if not finished:
        return LatencyReport(policy=policy, n_requests=0,
                             avg_per_token_latency=float("nan"),
                             p90_per_token_latency=float("nan"),
                             avg_ttft=float("nan"), makespan=0.0,
                             throughput_tok_s=0.0, mean_wait=float("nan"))
    per_tok = np.array([r.per_token_latency() for r in finished])
    ttft = np.array([(r.first_token_time - r.arrival_time) for r in finished
                     if r.first_token_time is not None])
    waits = np.array([(r.start_time - r.arrival_time) for r in finished
                      if r.start_time is not None])
    itl = itl_samples(finished)
    t0 = min(r.arrival_time for r in finished)
    t1 = max(r.finish_time for r in finished)
    tokens = sum(r.true_length for r in finished)
    cached = np.asarray([r.cached_prefix_tokens for r in finished
                         if r.cached_prefix_tokens is not None], dtype=float)
    growf = np.asarray([r.grow_failures for r in finished
                        if r.grow_failures is not None], dtype=float)
    growp = np.asarray([r.grow_preemptions for r in finished
                        if r.grow_preemptions is not None], dtype=float)
    return LatencyReport(
        policy=policy,
        n_requests=len(finished),
        avg_per_token_latency=_mean(per_tok),
        p90_per_token_latency=float(np.percentile(per_tok, 90)),
        avg_ttft=_mean(ttft),
        makespan=float(t1 - t0),
        throughput_tok_s=float(tokens / max(t1 - t0, 1e-9)),
        mean_wait=_mean(waits),
        p99_ttft=_pct(ttft, 99),
        p50_itl=_pct(itl, 50),
        p99_itl=_pct(itl, 99),
        prefix_hit_rate=_mean(cached > 0),
        prefill_tokens_saved=float(cached.sum()) if len(cached)
        else float("nan"),
        grow_failures=float(growf.sum()) if len(growf) else float("nan"),
        grow_preemptions=float(growp.sum()) if len(growp) else float("nan"),
    )
