"""Serving runtime: unified ServingCore loop, real JAX backend, discrete-event
simulator backend, KV accounting, multi-replica router front-end, declarative
multi-tenant SLO workloads."""
from repro.serving.config import ServingConfig, resolve_config
from repro.serving.core import (PrefillChunk, ServingCore, VirtualClock,
                                WallClock)
from repro.serving.engine import Engine, RealBackend, serve
from repro.serving.faults import (ArrivalSkew, FaultSchedule, GrowStorm,
                                  ReplicaCrash, ReplicaCrashed, ScorerError,
                                  ScorerOutage, ScorerTimeout)
from repro.serving.kv_cache import BlockAllocator, prefix_chunk_hashes
from repro.serving.metrics import (ClassSLOStats, LatencyReport, RouterReport,
                                   RunCounters, SLOReport, TenantStats,
                                   itl_samples, meets_itl, meets_slo,
                                   meets_ttft, report, router_report,
                                   slo_report)
from repro.serving.router import (ROUTING_POLICIES, ReplicaRouter,
                                  score_predicted_len)
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.simulator import (CostModel, SimBackend, clone_requests,
                                     make_sim_core, make_sim_replicas,
                                     run_policy, simulate, simulate_replicas)
from repro.serving.workloads import (SLO, ArrivalPhase, ConversationSpec,
                                     OutputDist, PriorityClass, TenantSpec,
                                     WorkloadSpec, generate_trace,
                                     trace_summary)
