"""Serving runtime: real JAX engine, discrete-event simulator, KV accounting."""
from repro.serving.engine import Engine, serve
from repro.serving.kv_cache import BlockAllocator
from repro.serving.metrics import LatencyReport, report
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.simulator import CostModel, run_policy, simulate
