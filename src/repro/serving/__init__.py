"""Serving runtime: unified ServingCore loop, real JAX backend, discrete-event
simulator backend, KV accounting."""
from repro.serving.core import (PrefillChunk, ServingCore, VirtualClock,
                                WallClock)
from repro.serving.engine import Engine, RealBackend, serve
from repro.serving.kv_cache import BlockAllocator, prefix_chunk_hashes
from repro.serving.metrics import LatencyReport, itl_samples, report
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.simulator import CostModel, SimBackend, run_policy, simulate
