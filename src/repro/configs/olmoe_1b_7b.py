"""OLMoE-1B-7B [arXiv:2409.02060].

[moe] 16L d_model=2048 16H (GQA kv=16 → MHA) d_ff=1024 vocab=50304,
MoE 64 experts top-8 (no shared expert).
"""
from repro.configs.base import ModelConfig, MoEConfig, MOE, ACT_SILU

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b",
    family=MOE,
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,                     # no shared dense FFN path
    vocab_size=50304,
    activation=ACT_SILU,
    use_bias=False,
    norm="rmsnorm",
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=64, top_k=8, expert_d_ff=1024),
    source="arXiv:2409.02060",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=256, group_size=64),
    )
