"""Architecture & input-shape configs (one module per assigned architecture)."""
from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    LONG_CONTEXT_WINDOW,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    all_configs,
    canon,
    config_for_shape,
    get_config,
    get_smoke_config,
    shape_applicable,
)

__all__ = [
    "ARCH_IDS", "INPUT_SHAPES", "LONG_CONTEXT_WINDOW", "InputShape",
    "ModelConfig", "MoEConfig", "SSMConfig", "all_configs", "canon",
    "config_for_shape", "get_config", "get_smoke_config", "shape_applicable",
]
