"""Hymba-1.5B [arXiv:2411.13676].

[hybrid] 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16 —
parallel attention + Mamba heads fused in every layer. Hymba uses sliding-
window attention in most layers; we set window=1024 so the attention-side KV
cache is bounded and ``long_500k`` runs natively (the SSM side is O(1)/token).
Meta-tokens are omitted (orthogonal to the scheduling study — DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, SSMConfig, HYBRID, ACT_SILU

CONFIG = ModelConfig(
    arch_id="hymba-1.5b",
    family=HYBRID,
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    activation=ACT_SILU,
    use_bias=False,
    norm="rmsnorm",
    rope_theta=10_000.0,
    sliding_window=1024,
    ssm=SSMConfig(state_size=16, conv_width=4, expand=2, head_dim=64,
                  chunk_size=128),
    source="arXiv:2411.13676",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, sliding_window=64,
        ssm=SSMConfig(state_size=16, conv_width=4, expand=2, head_dim=64,
                      chunk_size=32),
    )
