"""RWKV-6 "Finch" 7B [arXiv:2404.05892].

[ssm] 32L d_model=4096 (attention-free) d_ff=14336 vocab=65536 —
data-dependent decay linear attention (time-mix) + gated channel-mix.
Heads of size 64 → 64 heads. Decode state is O(H·dh²) per layer — constant in
sequence length, so ``long_500k`` runs natively.
"""
from repro.configs.base import ModelConfig, SSM

CONFIG = ModelConfig(
    arch_id="rwkv6-7b",
    family=SSM,
    num_layers=32,
    d_model=4096,
    num_heads=64,                # rwkv6 head size 64
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    use_bias=False,
    norm="layernorm",
    pos_emb="none",              # recurrence encodes position
    source="arXiv:2404.05892",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=512,
    )
