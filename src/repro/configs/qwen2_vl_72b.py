"""Qwen2-VL-72B language backbone [arXiv:2409.12191].

[vlm] 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 — M-RoPE,
dynamic resolution. Vision encoder (ViT) is a STUB per the assignment: the
backbone consumes precomputed patch embeddings supplied by ``input_specs``.
"""
from repro.configs.base import ModelConfig, VLM, ACT_SILU

CONFIG = ModelConfig(
    arch_id="qwen2-vl-72b",
    family=VLM,
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    activation=ACT_SILU,
    use_bias=False,
    norm="rmsnorm",
    pos_emb="mrope",            # multimodal RoPE: (temporal, height, width)
    rope_theta=1_000_000.0,
    vision_prefix_len=256,      # stub patch-embedding positions in training
    source="arXiv:2409.12191",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512, vision_prefix_len=8,
    )
