"""Cohere Command-R 35B [hf:CohereForAI/c4ai-command-r-v01].

[dense] 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000 — GQA, no-bias.
Command-R uses LayerNorm (no bias) and parallel attn/MLP blocks; we keep the
sequential pre-norm block (the scheduling study is insensitive to this) but
keep the published LayerNorm choice.
"""
from repro.configs.base import ModelConfig, DENSE, ACT_SILU

CONFIG = ModelConfig(
    arch_id="command-r-35b",
    family=DENSE,
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    activation=ACT_SILU,
    use_bias=False,
    norm="layernorm",
    rope_theta=8_000_000.0,
    tie_embeddings=True,        # Command-R ties input/output embeddings
    source="hf:CohereForAI/c4ai-command-r-v01",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512,
    )
