"""Whisper-tiny [arXiv:2212.04356].

[audio] 4L d_model=384 6H (MHA kv=6) d_ff=1536 vocab=51865 — encoder-decoder
with conv/mel frontend STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (1500 frames after the conv stride-2 stack).
Skipped for ``long_500k`` (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, AUDIO, ACT_GELU

CONFIG = ModelConfig(
    arch_id="whisper-tiny",
    family=AUDIO,
    num_layers=4,                # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    activation=ACT_GELU,
    use_bias=True,
    norm="layernorm",
    pos_emb="learned",
    tie_embeddings=True,
    encoder_layers=4,
    encoder_seq_len=1500,        # 30 s audio → 1500 post-conv frames
    source="arXiv:2212.04356",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512, encoder_layers=2, encoder_seq_len=64,
    )
