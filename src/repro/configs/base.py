"""Model / input-shape configuration system.

Every assigned architecture gets one ``<arch_id>.py`` module in this package
exporting ``CONFIG`` (a :class:`ModelConfig` at the exact published size) and
``smoke_config()`` (a reduced same-family variant for CPU tests).

The config is a plain frozen dataclass — no framework magic — so it can be
hashed into jit static args and printed into experiment logs verbatim.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"          # attention-free (RWKV6)
HYBRID = "hybrid"    # parallel attention + SSM heads (Hymba)
VLM = "vlm"          # decoder LM consuming stub patch embeddings
AUDIO = "audio"      # encoder-decoder consuming stub frame embeddings

FAMILIES = (DENSE, MOE, SSM, HYBRID, VLM, AUDIO)

# Activation functions understood by models/layers.py
ACT_SILU = "silu"            # gated SiLU (SwiGLU)
ACT_SQ_RELU = "squared_relu" # Nemotron-4
ACT_GELU = "gelu"            # whisper / BERT-style


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts block configuration (GLaM-style grouped dispatch)."""
    num_experts: int
    top_k: int
    expert_d_ff: int            # per-expert FFN hidden size
    capacity_factor: float = 1.25
    group_size: int = 2048      # tokens per dispatch group (sharding grain)
    router_z_coef: float = 1e-3 # router z-loss
    aux_loss_coef: float = 1e-2 # load-balance loss


@dataclass(frozen=True)
class SSMConfig:
    """SSD/Mamba2-style selective-state-space configuration."""
    state_size: int = 16        # N — per-channel state width
    conv_width: int = 4         # depthwise conv kernel (decode keeps a tail)
    expand: int = 2             # d_inner = expand * d_model
    head_dim: int = 64          # SSD head dim
    chunk_size: int = 128       # chunked-scan block length


@dataclass(frozen=True)
class ModelConfig:
    """One architecture, exactly as published (or its reduced smoke variant)."""
    arch_id: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int               # query heads (0 for attention-free)
    num_kv_heads: int            # GQA kv heads
    d_ff: int                    # dense-MLP hidden (MoE archs: shared/dense path, 0 if none)
    vocab_size: int
    head_dim: int = 128
    activation: str = ACT_SILU
    use_bias: bool = False
    norm: str = "rmsnorm"        # or "layernorm"
    tie_embeddings: bool = False

    # Positional encoding: "rope" | "mrope" (Qwen2-VL) | "learned" | "none"
    pos_emb: str = "rope"
    rope_theta: float = 500_000.0

    # Attention window: None = full causal. Set (or auto-set for long_500k)
    # to make attention sub-quadratic with a bounded KV cache.
    sliding_window: Optional[int] = None

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # Encoder-decoder (whisper): encoder layer count + fixed source length.
    encoder_layers: int = 0
    encoder_seq_len: int = 0     # e.g. 1500 mel frames after conv stub

    # VLM: number of stub patch-embedding positions prepended in training.
    vision_prefix_len: int = 0

    max_seq_len: int = 1_048_576
    dtype: str = "bfloat16"
    source: str = ""             # citation from the assignment table

    # ---- derived ---------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.family == SSM

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline."""
        d, v = self.d_model, self.vocab_size
        n = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if not self.attention_free:
            qkv = d * (self.num_heads + 2 * self.num_kv_heads) * self.head_dim
            o = self.num_heads * self.head_dim * d
            per_layer += qkv + o
        else:
            # rwkv6 time-mix: r,k,v,g,o (d*d each) + decay/ lora-ish small
            per_layer += 5 * d * d
        if self.moe is not None:
            e = self.moe
            per_layer += e.num_experts * (3 * d * e.expert_d_ff) + d * e.num_experts
            if self.d_ff:
                per_layer += 3 * d * self.d_ff          # shared dense path
        elif self.family == SSM:
            per_layer += 3 * d * self.d_ff              # rwkv channel-mix ~ gated mlp
        else:
            mult = 3 if self.activation == ACT_SILU else 2
            per_layer += mult * d * self.d_ff
        if self.ssm is not None and self.family == HYBRID:
            d_in = self.ssm.expand * d
            per_layer += 2 * d * d_in + d_in * d + d_in * (2 * self.ssm.state_size)
        n += self.num_layers * per_layer
        if self.is_encdec:
            enc_layer = 4 * d * self.num_heads * self.head_dim + 2 * d * self.d_ff
            n += self.encoder_layers * enc_layer
            # decoder cross-attention
            n += self.num_layers * 4 * d * self.num_heads * self.head_dim
        return int(n)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        dense = self.param_count() - self.num_layers * e.num_experts * 3 * self.d_model * e.expert_d_ff
        return int(dense + self.num_layers * e.top_k * 3 * self.d_model * e.expert_d_ff)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}

# Window applied to full-attention archs when running long_500k (DESIGN.md §5).
LONG_CONTEXT_WINDOW = 8_192


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """Whether (arch, shape) is part of the dry-run matrix (DESIGN.md §5)."""
    if shape.name == "long_500k" and cfg.family == AUDIO:
        return False  # whisper: no sub-quadratic variant in family — skipped
    return True


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Adapt a config to an input shape (sliding-window for long_500k)."""
    if shape.name == "long_500k" and not cfg.attention_free and cfg.sliding_window is None:
        return cfg.replace(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
ARCH_IDS = (
    "qwen2_vl_72b",
    "command_r_35b",
    "nemotron_4_15b",
    "olmoe_1b_7b",
    "llama3_2_3b",
    "kimi_k2_1t_a32b",
    "hymba_1_5b",
    "whisper_tiny",
    "moonshot_v1_16b_a3b",
    "rwkv6_7b",
)

# CLI ids (dashes) -> module ids (underscores)
def canon(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    import importlib
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    import importlib
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.smoke_config()


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
