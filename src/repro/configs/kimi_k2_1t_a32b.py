"""Kimi K2 — trillion-parameter MoE (paper-table config) [arXiv:2501.kimi2].

[moe] 61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840,
MoE 384 experts top-8 + 1 shared expert (DeepSeek-V3-style).
Assignment specifies GQA kv=8 (we follow it; the real model uses MLA).
"""
from repro.configs.base import ModelConfig, MoEConfig, MOE, ACT_SILU

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b",
    family=MOE,
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,                  # shared-expert dense path
    vocab_size=163840,
    activation=ACT_SILU,
    use_bias=False,
    norm="rmsnorm",
    rope_theta=50_000.0,
    moe=MoEConfig(num_experts=384, top_k=8, expert_d_ff=2048,
                  capacity_factor=1.25, group_size=2048),
    source="arXiv:2501.kimi2",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=256, group_size=64),
    )
