"""Nemotron-4 15B [arXiv:2402.16819].

[dense] 32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000 — GQA,
squared-ReLU MLP (non-gated, 2 matrices).
"""
from repro.configs.base import ModelConfig, DENSE, ACT_SQ_RELU

CONFIG = ModelConfig(
    arch_id="nemotron-4-15b",
    family=DENSE,
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    activation=ACT_SQ_RELU,
    use_bias=False,
    norm="layernorm",
    rope_theta=10_000.0,
    source="arXiv:2402.16819",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512,
    )
