"""Moonshot Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B].

Assignment pool label is [dense] but the spec line explicitly lists
"MoE 64e top-6" — we implement the explicit expert spec (DESIGN.md §5):
48L d_model=2048 16H (MHA kv=16) expert d_ff=1408 vocab=163840,
MoE 64 experts top-6 + shared dense path (DeepSeek-V3-style).
"""
from repro.configs.base import ModelConfig, MoEConfig, MOE, ACT_SILU

CONFIG = ModelConfig(
    arch_id="moonshot-v1-16b-a3b",
    family=MOE,
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                  # shared-expert dense path
    vocab_size=163840,
    activation=ACT_SILU,
    use_bias=False,
    norm="rmsnorm",
    rope_theta=50_000.0,
    moe=MoEConfig(num_experts=64, top_k=6, expert_d_ff=1408,
                  capacity_factor=1.25, group_size=2048),
    source="hf:moonshotai/Moonlight-16B-A3B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=256, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=256, group_size=64),
    )
