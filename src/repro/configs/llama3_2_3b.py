"""Llama 3.2 3B [hf:meta-llama/Llama-3.2-1B family].

[dense] 28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256 — small llama3.
"""
from repro.configs.base import ModelConfig, DENSE, ACT_SILU

CONFIG = ModelConfig(
    arch_id="llama3.2-3b",
    family=DENSE,
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    activation=ACT_SILU,
    use_bias=False,
    norm="rmsnorm",
    rope_theta=500_000.0,
    tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512,
    )
