"""The paper's own predictor architecture: BERT-base-uncased [paper §III-A].

Not one of the 10 assigned serving architectures — this is the *scheduler's*
model. ``CONFIG`` is the faithful BERT-base size (110M; what you train on real
hardware); ``smoke_config()`` is the container-scale mini used by default in
benchmarks (DESIGN.md §8).
"""
from repro.core.predictor.backbones import PredictorConfig

CONFIG = PredictorConfig(
    backbone="bert",
    vocab_size=30522,        # bert-base-uncased WordPiece
    max_len=128,
    d_model=768,
    num_heads=12,
    num_layers=12,
    d_ff=3072,
)


def smoke_config() -> PredictorConfig:
    return PredictorConfig()     # the repo-wide mini default
