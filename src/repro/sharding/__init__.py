"""Sharding: logical-axis annotation + parameter partition specs."""
from repro.sharding.annotate import (DEFAULT_RULES, logical_axis_rules,
                                     resolve_spec, with_sharding)

__all__ = ["DEFAULT_RULES", "logical_axis_rules", "resolve_spec", "with_sharding"]
