"""Logical-axis sharding annotations.

Model code tags activations with *logical* axis names ("batch", "heads",
"expert", ...). A rules context maps logical names to physical mesh axes;
outside any rules context the tags are no-ops, so the same model code runs
un-sharded on CPU smoke tests and fully sharded under the production mesh.

Non-divisible dims are silently left unsharded (e.g. a decode step with one
MoE group under a 16-way axis) — GSPMD would reject the constraint otherwise.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX = threading.local()

Axes = Union[None, str, Tuple[str, ...]]


def _state():
    if not hasattr(_CTX, "stack"):
        _CTX.stack = []
    return _CTX.stack


@contextmanager
def logical_axis_rules(mesh: Mesh, rules: Dict[str, Axes]):
    """Activate a logical→physical mapping for ``with_sharding`` tags."""
    _state().append((mesh, rules))
    try:
        yield
    finally:
        _state().pop()


def current_mesh_rules() -> Optional[Tuple[Mesh, Dict[str, Axes]]]:
    st = _state()
    return st[-1] if st else None


def resolve_spec(logical: Sequence[Axes], shape, mesh: Mesh,
                 rules: Dict[str, Axes]) -> P:
    """Map logical axis names to a PartitionSpec, dropping non-divisible dims."""
    out = []
    used: set = set()
    for dim, name in enumerate(logical):
        phys = rules.get(name) if isinstance(name, str) else None
        if phys is None:
            out.append(None)
            continue
        axes = (phys,) if isinstance(phys, str) else tuple(phys)
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if size > 1 and shape[dim] % size == 0:
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def with_sharding(x: jax.Array, logical: Sequence[Axes]) -> jax.Array:
    """Tag an intermediate with logical axes (no-op without active rules)."""
    ctx = current_mesh_rules()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve_spec(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# Default logical→physical mapping for the production meshes (DESIGN.md §6).
# "data"-like axes absorb the "pod" axis when it exists.
DEFAULT_RULES: Dict[str, Axes] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),        # param FSDP dim
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "expert": "model",
    "vocab": "model",
    "moe_group": ("data", "model"),  # dispatch groups, fully token-sharded
    "moe_group_dp": ("pod", "data"), # groups in the (G,E,C,d) expert layout
    "seq": None,                     # sequence kept unsharded (no CP here)
    "d_inner": "model",              # SSM inner channels
}
