"""Parameter / activation / cache partition specs for the production meshes.

Policy (DESIGN.md §6): tensor-parallel dims (heads, ff, experts, vocab) shard
over ``model``; one non-TP matrix dim shards over the FSDP axes
(``data`` or ``("pod","data")``); everything indivisible or tiny is
replicated. Specs are derived *by leaf path name*, so every architecture
family (dense/MoE/RWKV/SSM/enc-dec) is covered by one rule table.

``decode_cache_specs`` has two modes (the §Perf hillclimb for decode):

* ``kv_shard="heads"`` — baseline: kv-head dim over ``model``. GQA configs
  with kv_heads < 16 cannot split 16 ways, the dim is dropped and the cache is
  replicated across ``model`` (memory-hungry — visible in the roofline).
* ``kv_shard="seq"``   — optimized: cache *length* dim over ``model``
  (sequence-sharded decode; XLA inserts the partial-softmax reductions).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# leaf-name regex -> logical spec template, aligned to the LAST ndims of the
# leaf (leading stacked-layer axes are padded with None automatically).
# Axis vocabulary: "fsdp" | "model" | None.
_RULES: Tuple[Tuple[str, Tuple], ...] = (
    # embeddings / heads
    (r"(^|/)embed$",            ("model", "fsdp")),     # (V, d)
    (r"(^|/)lm_head$",          ("fsdp", "model")),     # (d, V)
    (r"(^|/)pos_embed$",        (None, "fsdp")),
    # attention
    (r"/attn/w[qkv]$",          ("fsdp", "model")),
    (r"/attn/wo$",              ("model", "fsdp")),
    (r"/cross/w[qkv]$",         ("fsdp", "model")),
    (r"/cross/wo$",             ("model", "fsdp")),
    # dense MLP
    (r"/mlp/w_(up|gate)$",      ("fsdp", "model")),
    (r"/mlp/w_down$",           ("model", "fsdp")),
    # MoE
    (r"/moe/router$",           ("fsdp", "model")),     # (d, E)
    (r"/moe/w_(up|gate)$",      ("model", "fsdp", None)),  # (E, d, f)
    (r"/moe/w_down$",           ("model", None, "fsdp")),  # (E, f, d)
    # RWKV time-mix / channel-mix
    (r"/tm/w_[rkvg]$",          ("fsdp", "model")),
    (r"/tm/w_o$",               ("model", "fsdp")),
    (r"/tm/decay_a$",           ("fsdp", None)),
    (r"/tm/decay_b$",           (None, "model")),
    (r"/cm/w_[kr]$",            ("fsdp", "model")),
    (r"/cm/w_v$",               ("model", "fsdp")),
    # SSD/Mamba (hybrid)
    (r"/ssm/w_in$",             ("fsdp", "model")),
    (r"/ssm/conv$",             (None, "model")),
    (r"/ssm/w_(bc|dt)$",        ("model", None)),
    (r"/ssm/w_out$",            ("model", "fsdp")),
)


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return fsdp_axes(mesh)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _resolve(template, shape, mesh: Mesh, *, fsdp: bool = True) -> P:
    """Logical template → PartitionSpec, padded to ndim, divisibility-checked.

    ``fsdp=False`` drops the FSDP dim (params replicated over the data axes —
    the TP-only layout used for weight-resident decode, §Perf iteration B4).
    """
    tpl = (None,) * (len(shape) - len(template)) + tuple(template)
    out, used = [], set()
    for dim, name in zip(shape, tpl):
        if name is None or (name == "fsdp" and not fsdp):
            out.append(None)
            continue
        axes = fsdp_axes(mesh) if name == "fsdp" else ("model",)
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        if axes and dim % _axis_size(mesh, axes) == 0:
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def _leaf_path(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_specs(params_shape: PyTree, mesh: Mesh, *, fsdp: bool = True) -> PyTree:
    """NamedSharding pytree for a params (or ShapeDtypeStruct) pytree."""
    def spec_for(path, leaf):
        name = _leaf_path(path)
        for pat, tpl in _RULES:
            if re.search(pat, name):
                return NamedSharding(mesh,
                                     _resolve(tpl, leaf.shape, mesh, fsdp=fsdp))
        return NamedSharding(mesh, P())          # replicate (norms, scalars…)
    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def batch_specs(batch_shape: PyTree, mesh: Mesh) -> PyTree:
    """Shard every batch leaf's batch dim over (pod, data).

    Handles the (3, B, S) mrope-positions layout (batch at axis 1).
    """
    ba = batch_axes(mesh)

    def spec_for(path, leaf):
        name = _leaf_path(path)
        bdim = 1 if name.endswith("mrope_positions") else 0
        spec = [None] * len(leaf.shape)
        if leaf.shape[bdim] % _axis_size(mesh, ba) == 0:
            spec[bdim] = ba if len(ba) > 1 else ba[0]
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


def decode_cache_specs(cache_shape: PyTree, mesh: Mesh, *,
                       kv_shard: str = "heads") -> PyTree:
    """Cache pytree specs. Leaves are (L, B, ...) stacked; pos is scalar."""
    ba = batch_axes(mesh)
    assert kv_shard in ("heads", "seq")

    def spec_for(path, leaf):
        name = _leaf_path(path)
        if leaf.ndim == 0:                       # pos scalar
            return NamedSharding(mesh, P())
        spec = [None] * leaf.ndim
        if leaf.ndim >= 2 and leaf.shape[1] % _axis_size(mesh, ba) == 0:
            spec[1] = ba if len(ba) > 1 else ba[0]     # batch dim
        msize = mesh.shape.get("model", 1)
        if re.search(r"(^|/)(k|v|cross_k|cross_v)$", name) and leaf.ndim == 5:
            # (L, B, W, KH, dh)
            if kv_shard == "seq" and leaf.shape[2] % msize == 0:
                spec[2] = "model"
            elif kv_shard == "heads" and leaf.shape[3] % msize == 0:
                spec[3] = "model"
        elif re.search(r"/(state|ssm_state)$", name) and leaf.ndim >= 3:
            if leaf.shape[2] % msize == 0:       # heads dim of the state
                spec[2] = "model"
        elif re.search(r"/conv_tail$", name) and leaf.ndim == 4:
            if leaf.shape[3] % msize == 0:       # d_inner
                spec[3] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
