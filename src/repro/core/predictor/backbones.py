"""Mini Transformer backbones for the ranking predictor: BERT / OPT / T5.

Reproduces the paper's Table III backbone comparison at laptop scale
(DESIGN.md §8): the *method* — encode prompt → pooled feature → linear scalar
score — is identical; the backbones are trained from scratch.

* ``bert`` — bidirectional encoder; feature = tanh(W·h[CLS]) (BERT pooler).
* ``opt``  — causal decoder; feature = hidden of the last non-pad token.
* ``t5``   — encoder + one-query attention-pooling "decoder" (mini analogue
  of T5's enc-dec readout).

All backbones share one stacked-layer transformer body (lax.scan).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.predictor.tokenizer import PAD
from repro.models.attention import attention_naive
from repro.models.common import dense_init, embed_init

PyTree = Any

BACKBONES = ("bert", "opt", "t5")


@dataclass(frozen=True)
class PredictorConfig:
    """Defaults sized for the 1-core CPU container (DESIGN.md §8): the paper
    uses BERT-base (110M); the method is scale-free, so the repro default is a
    ~0.4M-param mini. Pass a larger config on real hardware."""
    backbone: str = "bert"
    vocab_size: int = 2048
    max_len: int = 32
    d_model: int = 64
    num_heads: int = 2
    num_layers: int = 2
    d_ff: int = 192


def _init_block(key, cfg: PredictorConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, d)), "wk": dense_init(ks[1], (d, d)),
        "wv": dense_init(ks[2], (d, d)), "wo": dense_init(ks[3], (d, d)),
        "w1": dense_init(ks[4], (d, f)), "w2": dense_init(ks[5], (f, d), in_axis_size=f),
        "ln1": jnp.ones((d,)), "ln1b": jnp.zeros((d,)),
        "ln2": jnp.ones((d,)), "ln2b": jnp.zeros((d,)),
    }


def init_predictor(key, cfg: PredictorConfig) -> PyTree:
    ks = jax.random.split(key, 6)
    p = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model)),
        "pos": embed_init(ks[1], (cfg.max_len, cfg.d_model)),
        "layers": jax.vmap(lambda k: _init_block(k, cfg))(
            jax.random.split(ks[2], cfg.num_layers)),
        "ln_f": jnp.ones((cfg.d_model,)), "ln_fb": jnp.zeros((cfg.d_model,)),
        "head": dense_init(ks[3], (cfg.d_model, 1)),
    }
    if cfg.backbone == "bert":
        p["pooler"] = dense_init(ks[4], (cfg.d_model, cfg.d_model))
    if cfg.backbone == "t5":
        p["pool_query"] = embed_init(ks[5], (1, cfg.d_model))
    return p


def _ln(x, scale, bias):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias).astype(x.dtype)


def _body(cfg: PredictorConfig, x, pos_kv, positions, causal):
    h = cfg.num_heads
    dh = cfg.d_model // h

    def block(x, lp):
        b, s, d = x.shape
        xn = _ln(x, lp["ln1"], lp["ln1b"])
        q = (xn @ lp["wq"]).reshape(b, s, h, dh)
        k = (xn @ lp["wk"]).reshape(b, s, h, dh)
        v = (xn @ lp["wv"]).reshape(b, s, h, dh)
        att = attention_naive(q, k, v, positions, pos_kv, causal=causal)
        x = x + att.reshape(b, s, d) @ lp["wo"]
        xn = _ln(x, lp["ln2"], lp["ln2b"])
        x = x + jax.nn.gelu(xn @ lp["w1"]) @ lp["w2"]
        return x, None
    return block


def predictor_forward(params: PyTree, cfg: PredictorConfig,
                      tokens: jax.Array) -> jax.Array:
    """tokens: (B, T) int32 → scores (B,) f32. Higher = longer expected output."""
    b, t = tokens.shape
    pad_mask = tokens != PAD
    x = params["embed"][tokens] + params["pos"][None, :t]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    pos_kv = jnp.where(pad_mask, positions, -1)      # PAD slots masked out
    causal = cfg.backbone == "opt"
    x, _ = jax.lax.scan(_body(cfg, x, pos_kv, positions, causal),
                        x, params["layers"])
    x = _ln(x, params["ln_f"], params["ln_fb"])

    if cfg.backbone == "bert":
        feat = jnp.tanh(x[:, 0] @ params["pooler"])          # [CLS] pooler
    elif cfg.backbone == "opt":
        last = jnp.maximum(jnp.sum(pad_mask, -1) - 1, 0)     # last real token
        feat = x[jnp.arange(b), last]
    else:  # t5: one-query attention pooling over encoder states
        q = jnp.broadcast_to(params["pool_query"][None], (b, 1, cfg.d_model))
        scores = jnp.einsum("bqd,btd->bqt", q, x) / jnp.sqrt(cfg.d_model)
        scores = jnp.where(pad_mask[:, None], scores, -1e30)
        feat = jnp.einsum("bqt,btd->bqd", jax.nn.softmax(scores, -1), x)[:, 0]
    return (feat @ params["head"])[:, 0].astype(jnp.float32)
