"""Kendall rank correlation τ_b (tie-corrected), exactly as in the paper §IV:

    τ_b = (n_c − n_d) / sqrt((n_0 − n_1)(n_0 − n_2))

with n_0 = n(n−1)/2 and n_1 / n_2 the tied-pair counts of each variable.
"""
from __future__ import annotations

import numpy as np


def kendall_tau_b(x, y) -> float:
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    n = len(x)
    assert len(y) == n and n >= 2
    dx = np.sign(x[:, None] - x[None, :])
    dy = np.sign(y[:, None] - y[None, :])
    iu = np.triu_indices(n, k=1)
    prod = dx[iu] * dy[iu]
    n_c = int(np.sum(prod > 0))
    n_d = int(np.sum(prod < 0))
    n0 = n * (n - 1) // 2
    n1 = int(np.sum(dx[iu] == 0))
    n2 = int(np.sum(dy[iu] == 0))
    denom = np.sqrt(float(n0 - n1) * float(n0 - n2))
    return float((n_c - n_d) / denom) if denom > 0 else 0.0
