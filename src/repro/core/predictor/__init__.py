"""Ranking predictor (paper §III-A): backbones, losses, pairing, training."""
from repro.core.predictor.backbones import BACKBONES, PredictorConfig, init_predictor, predictor_forward
from repro.core.predictor.losses import l1_pointwise_loss, listmle_loss, margin_ranking_loss
from repro.core.predictor.metrics import kendall_tau_b
from repro.core.predictor.pairing import DELTA_INSTRUCT, DELTA_REASONING, build_pairs, min_length_difference
from repro.core.predictor.tokenizer import HashTokenizer
from repro.core.predictor.train import (METHODS, RankingPredictor, TrainSettings,
                                        evaluate_tau, train_predictor)
