"""Training-pair construction with min_length_difference filtering (§III-A).

    min_length_difference = |L_A − L_B| / max(L_A, L_B)  ≥  δ

Pairs below δ are *dropped from training* — their ordering is within the
LLM's natural run-to-run output variance (~20% instruct / ~25% reasoning,
paper Fig. 2) and constitutes noise, not signal. δ defaults per model kind:
0.2 (instruct-class) / 0.25 (reasoning-class).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

DELTA_INSTRUCT = 0.20
DELTA_REASONING = 0.25


def min_length_difference(la: np.ndarray, lb: np.ndarray) -> np.ndarray:
    la = np.asarray(la, np.float64)
    lb = np.asarray(lb, np.float64)
    return np.abs(la - lb) / np.maximum(np.maximum(la, lb), 1.0)


def build_pairs(lengths: np.ndarray, rng: np.random.Generator, *,
                n_pairs: int, delta: float = DELTA_INSTRUCT,
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sample informative prompt pairs.

    Returns (idx_a, idx_b, y) with y=+1 iff lengths[idx_a] > lengths[idx_b].
    Oversamples then filters by δ, so the returned count can be < n_pairs
    when the length distribution is tight (matches the paper's protocol of
    training only on retained pairs).
    """
    n = len(lengths)
    factor = 4
    ia = rng.integers(0, n, n_pairs * factor)
    ib = rng.integers(0, n, n_pairs * factor)
    keep = (ia != ib) & (min_length_difference(lengths[ia], lengths[ib]) >= delta)
    ia, ib = ia[keep][:n_pairs], ib[keep][:n_pairs]
    y = np.where(lengths[ia] > lengths[ib], 1.0, -1.0).astype(np.float32)
    return ia, ib, y
