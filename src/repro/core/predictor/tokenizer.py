"""Deterministic hash tokenizer for the ranking predictor.

The paper uses BERT-base-uncased's WordPiece vocabulary; offline we use a
stable-hash word tokenizer (lowercase, split on non-alphanumerics, FNV-1a into
the vocab). What matters for the method is that prompt semantics map to
consistent token ids the predictor can learn from — which a hash vocab
provides (collisions act as mild label noise).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

PAD, CLS, UNK = 0, 1, 2
N_SPECIAL = 3
_WORD = re.compile(r"[a-z0-9]+")


def _fnv1a(word: str) -> int:
    h = 0xcbf29ce484222325
    for ch in word.encode():
        h = ((h ^ ch) * 0x100000001b3) & 0xFFFFFFFFFFFFFFFF
    return h


@dataclass(frozen=True)
class HashTokenizer:
    vocab_size: int = 2048
    max_len: int = 32

    def encode(self, text: str) -> List[int]:
        words = _WORD.findall(text.lower())
        ids = [CLS] + [N_SPECIAL + _fnv1a(w) % (self.vocab_size - N_SPECIAL)
                       for w in words]
        return ids[: self.max_len]

    def encode_batch(self, texts: Sequence[str]) -> np.ndarray:
        """(N, max_len) int32, PAD-padded; row 0 is always [CLS]."""
        out = np.full((len(texts), self.max_len), PAD, np.int32)
        for i, t in enumerate(texts):
            ids = self.encode(t)
            out[i, : len(ids)] = ids
        return out
