"""Learning-to-rank losses: margin-ranking (PARS), L1 pointwise, ListMLE.

The margin ranking loss is the paper's eq. in §III-A:
    L(s_A, s_B, y) = max(0, -y · (s_A - s_B) + margin),   margin = 1.0
with y = +1 when prompt A's response is expected to be *longer*.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

POINTWISE_SCALE = 100.0  # tokens per score unit — a practitioner-reasonable
# normalization for instruct-length outputs; reasoning-length outliers then
# dominate the L1 objective, which is exactly the pointwise failure mode the
# paper exploits (§II, Table II)


def margin_ranking_loss(s_a: jax.Array, s_b: jax.Array, y: jax.Array,
                        margin: float = 1.0) -> jax.Array:
    """Paper §III-A. s_a/s_b: (B,) scores; y: (B,) in {+1, -1}."""
    return jnp.mean(jnp.maximum(0.0, -y * (s_a - s_b) + margin))


def l1_pointwise_loss(scores: jax.Array, lengths: jax.Array) -> jax.Array:
    """Pointwise SJF baseline [Qiu et al.]: regression with L1 loss on the
    response length (scaled — τ_b only depends on ordering)."""
    return jnp.mean(jnp.abs(scores - lengths.astype(jnp.float32)
                            / POINTWISE_SCALE))


def listmle_loss(scores: jax.Array, lengths: jax.Array) -> jax.Array:
    """Listwise SJF baseline [Fu et al., ListMLE]: negative log-likelihood of
    the ground-truth descending-length permutation under the Plackett-Luce
    model. scores/lengths: (B, L) — B lists of L items."""
    order = jnp.argsort(-lengths, axis=-1)                 # longest first
    s = jnp.take_along_axis(scores, order, axis=-1)        # (B, L)
    # log P = Σ_i [ s_i − logsumexp(s_i..s_L) ]  (suffix logsumexp)
    rev = s[:, ::-1]
    suffix_lse = jax.lax.cumlogsumexp(rev, axis=1)[:, ::-1]
    return -jnp.mean(jnp.sum(s - suffix_lse, axis=-1))
