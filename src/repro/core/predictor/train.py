"""Predictor training: pairwise (PARS), pointwise (L1), listwise (ListMLE).

Protocol follows the paper §IV: Adam, 5 epochs, batch 128, margin 1.0,
δ-filtered pairs for PARS. The paper fine-tunes pretrained BERT-base at
lr 2e-5; our from-scratch mini backbones use lr 3e-4 (DESIGN.md §8).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.predictor.backbones import (PredictorConfig, init_predictor,
                                            predictor_forward)
from repro.core.predictor.losses import (l1_pointwise_loss, listmle_loss,
                                         margin_ranking_loss, POINTWISE_SCALE)
from repro.core.predictor.metrics import kendall_tau_b
from repro.core.predictor.pairing import build_pairs
from repro.core.predictor.tokenizer import HashTokenizer
from repro.training.optimizer import Adam, apply_updates

PyTree = Any

METHODS = ("pairwise", "pointwise", "listwise")


@dataclass
class TrainSettings:
    method: str = "pairwise"
    epochs: int = 5
    batch_size: int = 128
    learning_rate: float = 3e-4
    margin: float = 1.0
    delta: float = 0.20           # min_length_difference threshold (0 = off)
    pairs_per_epoch: int = 6_400
    list_size: int = 16           # listwise group size
    seed: int = 0


@dataclass
class RankingPredictor:
    """Trained predictor: ``score()`` maps prompts → expected-length scores.

    Higher score ⇒ longer expected response ⇒ *lower* SJF priority.
    """
    cfg: PredictorConfig
    params: PyTree
    tokenizer: HashTokenizer
    method: str = "pairwise"
    _jit_fwd: Any = field(default=None, repr=False)

    def __post_init__(self):
        self._jit_fwd = jax.jit(
            functools.partial(predictor_forward, cfg=self.cfg))

    def score_tokens(self, tokens: np.ndarray) -> np.ndarray:
        return np.asarray(self._jit_fwd(self.params, tokens=jnp.asarray(tokens)))

    def score(self, prompts) -> np.ndarray:
        return self.score_tokens(self.tokenizer.encode_batch(list(prompts)))

    # ------------------------------------------------------------ persistence
    def save(self, path: str) -> None:
        from repro.training.checkpoint import save_checkpoint
        meta = {"method": self.method, "backbone": self.cfg.backbone,
                **{k: getattr(self.cfg, k) for k in
                   ("vocab_size", "max_len", "d_model", "num_heads",
                    "num_layers", "d_ff")}}
        save_checkpoint(path, self.params, metadata=meta)

    @classmethod
    def load(cls, path: str) -> "RankingPredictor":
        import json
        from repro.core.predictor.backbones import (PredictorConfig,
                                                    init_predictor)
        from repro.training.checkpoint import load_checkpoint
        with open((path if path.endswith(".npz") else path + ".npz")
                  + ".json") as f:
            meta = json.load(f)["metadata"]
        cfg = PredictorConfig(
            backbone=meta["backbone"], vocab_size=meta["vocab_size"],
            max_len=meta["max_len"], d_model=meta["d_model"],
            num_heads=meta["num_heads"], num_layers=meta["num_layers"],
            d_ff=meta["d_ff"])
        like = init_predictor(jax.random.PRNGKey(0), cfg)
        params = load_checkpoint(path, like)
        tok = HashTokenizer(vocab_size=cfg.vocab_size, max_len=cfg.max_len)
        return cls(cfg=cfg, params=params, tokenizer=tok,
                   method=meta.get("method", "pairwise"))


def _make_loss(cfg: PredictorConfig, settings: TrainSettings):
    method = settings.method

    if method == "pairwise":
        def loss_fn(params, batch):
            s_a = predictor_forward(params, cfg, batch["tok_a"])
            s_b = predictor_forward(params, cfg, batch["tok_b"])
            return margin_ranking_loss(s_a, s_b, batch["y"], settings.margin)
    elif method == "pointwise":
        def loss_fn(params, batch):
            s = predictor_forward(params, cfg, batch["tokens"])
            return l1_pointwise_loss(s, batch["lengths"])
    elif method == "listwise":
        def loss_fn(params, batch):
            b, l, t = batch["tokens"].shape
            s = predictor_forward(params, cfg,
                                  batch["tokens"].reshape(b * l, t))
            return listmle_loss(s.reshape(b, l),
                                batch["lengths"].reshape(b, l))
    else:
        raise ValueError(f"unknown method {method!r}")
    return loss_fn


def train_predictor(prompts, lengths, *,
                    backbone: str = "bert",
                    settings: Optional[TrainSettings] = None,
                    tokenizer: Optional[HashTokenizer] = None,
                    pcfg: Optional[PredictorConfig] = None,
                    log_fn=None) -> RankingPredictor:
    """Train a ranking predictor on (prompt, ground-truth-length) data."""
    st = settings or TrainSettings()
    tok = tokenizer or HashTokenizer()
    cfg = pcfg or PredictorConfig(backbone=backbone, vocab_size=tok.vocab_size,
                                  max_len=tok.max_len)
    rng = np.random.default_rng(st.seed)
    tokens = tok.encode_batch(list(prompts))
    lengths = np.asarray(lengths, np.float32)

    params = init_predictor(jax.random.PRNGKey(st.seed), cfg)
    opt = Adam(learning_rate=st.learning_rate, clip_norm=1.0)
    opt_state = opt.init(params)
    loss_fn = _make_loss(cfg, st)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    n = len(tokens)
    bs = st.batch_size
    for epoch in range(st.epochs):
        losses = []
        if st.method == "pairwise":
            ia, ib, y = build_pairs(lengths, rng, n_pairs=st.pairs_per_epoch,
                                    delta=st.delta)
            for i in range(0, len(ia) - bs + 1, bs):
                batch = {"tok_a": jnp.asarray(tokens[ia[i:i + bs]]),
                         "tok_b": jnp.asarray(tokens[ib[i:i + bs]]),
                         "y": jnp.asarray(y[i:i + bs])}
                params, opt_state, loss = step(params, opt_state, batch)
                losses.append(float(loss))
        elif st.method == "pointwise":
            perm = rng.permutation(n)
            for i in range(0, n - bs + 1, bs):
                sel = perm[i:i + bs]
                batch = {"tokens": jnp.asarray(tokens[sel]),
                         "lengths": jnp.asarray(lengths[sel])}
                params, opt_state, loss = step(params, opt_state, batch)
                losses.append(float(loss))
        else:  # listwise
            ls = st.list_size
            n_lists = max(1, bs // ls)
            perm = rng.permutation(n - n % (ls * n_lists))
            groups = perm.reshape(-1, n_lists, ls)
            for grp in groups:
                batch = {"tokens": jnp.asarray(tokens[grp]),
                         "lengths": jnp.asarray(lengths[grp])}
                params, opt_state, loss = step(params, opt_state, batch)
                losses.append(float(loss))
        if log_fn:
            log_fn(f"[{st.method}/{backbone}] epoch {epoch}: "
                   f"loss {np.mean(losses):.4f} ({len(losses)} steps)")

    return RankingPredictor(cfg=cfg, params=params, tokenizer=tok,
                            method=st.method)


def evaluate_tau(predictor: RankingPredictor, prompts, lengths) -> float:
    """Kendall τ_b between predicted scores and ground-truth lengths."""
    scores = predictor.score(prompts)
    return kendall_tau_b(scores, np.asarray(lengths))
