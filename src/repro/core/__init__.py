"""PARS core: pairwise learning-to-rank predictor + predictor-guided scheduler."""
