"""Predictor-guided continuous-batching scheduler (paper §III-B).

vLLM-style two-queue structure:

* **Waiting queue (W)** — arrived, not yet executing. Re-ranked every
  scheduling cycle by the policy's priority key (ascending).
* **Running queue (R)** — currently in the engine's batch, capacity
  ``max_batch``. Under continuous batching, finished requests are replaced
  at iteration granularity; under static batching a whole batch must drain
  before W is consulted again.

Starvation prevention (paper default 2 minutes): any waiting request whose
wait time exceeds ``starvation_threshold`` has its priority boosted — boosted
requests are scheduled ahead of everything else, FIFO among themselves.

This object is shared verbatim by the real JAX engine and the discrete-event
simulator; only the clock source differs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

from repro.core.scheduler.policies import Policy
from repro.core.scheduler.request import Request, RequestState

DEFAULT_STARVATION_S = 120.0


@dataclass
class Scheduler:
    policy: Policy
    max_batch: int = 16
    starvation_threshold: float = DEFAULT_STARVATION_S
    continuous: bool = True            # False = static batching
    # vLLM-style recompute preemption (beyond-paper, off by default): when R
    # is full and a waiting request's priority key undercuts a running one by
    # more than ``preempt_margin``, the worst running request is evicted back
    # to W (losing its KV cache — on re-admission it re-prefills prompt +
    # already-generated tokens, which the simulator charges). Bounded per
    # request by ``max_preemptions`` to prevent thrash; boosted requests are
    # never preempted.
    preemption: bool = False
    preempt_margin: float = 0.0
    max_preemptions: int = 2
    # KV-budget awareness (installed by ServingCore): ``admit_hook`` is the
    # admission gate — called in rank order, it reserves cache blocks and
    # returns False to keep a request in W this cycle (memory back-pressure
    # without queue surgery). ``evict_hook`` releases a preemption victim's
    # reservation and backend residency. Both are optional so the scheduler
    # stays usable standalone in unit tests.
    admit_hook: Optional[Callable[[Request], bool]] = None
    evict_hook: Optional[Callable[[Request], None]] = None
    waiting: List[Request] = field(default_factory=list)
    running: List[Request] = field(default_factory=list)

    # ------------------------------------------------------------------ API
    def add_request(self, req: Request) -> None:
        self.policy.annotate([req])
        req.state = RequestState.WAITING
        self.waiting.append(req)

    def add_requests(self, reqs: List[Request]) -> None:
        self.policy.annotate(reqs)
        for r in reqs:
            r.state = RequestState.WAITING
        self.waiting.extend(reqs)

    def _boost(self, now: float) -> None:
        for r in self.waiting:
            if not r.boosted and now - r.arrival_time > self.starvation_threshold:
                r.boosted = True

    def _rank(self) -> None:
        """Sort W: boosted first (FIFO among them), then policy key, then
        arrival (stable tiebreak)."""
        self.waiting.sort(
            key=lambda r: ((0, r.arrival_time, 0.0) if r.boosted
                           else (1, self.policy.key(r), r.arrival_time)))

    def schedule(self, now: float) -> List[Request]:
        """One scheduling cycle: move top-ranked W → R up to capacity.

        Returns the newly admitted requests (engine must prefill them).
        Under static batching, admission only happens when R is empty.
        """
        self.retire_finished(now)
        if not self.continuous and self.running:
            return []
        if self.preemption and self.waiting:
            self._boost(now)
            self._rank()
            self._preempt()
        free = self.max_batch - len(self.running)
        if free <= 0 or not self.waiting:
            return []
        self._boost(now)
        self._rank()
        if self.admit_hook is None:
            admitted = self.waiting[:free]
            del self.waiting[:free]
        else:
            admitted, kept = [], []
            for i, r in enumerate(self.waiting):
                if len(admitted) == free:
                    kept.extend(self.waiting[i:])
                    break
                (admitted if self.admit_hook(r) else kept).append(r)
            self.waiting = kept
        for r in admitted:
            r.state = RequestState.RUNNING
            r.start_time = now
        self.running.extend(admitted)
        return admitted

    def add_admit_gate(self, gate: Callable[[Request], bool]) -> None:
        """Compose an extra admission predicate with the installed
        ``admit_hook``. Admission then requires every gate *and* the base
        hook to accept; a gate returning False keeps the request in W this
        cycle, exactly like the hook itself. Gates added later run FIRST —
        cheap predicates evaluate before the serving core's hook reserves
        KV blocks, so a gate rejection can never leak a reservation. This
        is how a front-end above the core (the multi-replica router) vetoes
        or observes per-replica admissions through the same admission path
        instead of inventing a second gate mechanism."""
        base = self.admit_hook
        if base is None:
            self.admit_hook = gate
        else:
            def chained(r: Request, _gate=gate, _base=base) -> bool:
                return _gate(r) and _base(r)
            self.admit_hook = chained

    def defer(self, reqs: List[Request]) -> None:
        """Return admitted-but-unplaceable requests to the head of W (engine
        back-pressure through the scheduler API, not queue surgery). The
        caller is responsible for releasing any resources it reserved."""
        if not reqs:
            return
        self.running = [r for r in self.running if r not in reqs]
        for r in reqs:
            r.state = RequestState.WAITING
            r.prefilled_tokens = 0       # deferred residency is fully released
            r.prefill_target = None
        self.waiting[:0] = reqs

    def _preempt(self) -> None:
        """Evict worst-running in favour of strictly-better waiting requests
        (requires self.waiting already ranked)."""
        while len(self.running) >= self.max_batch and self.waiting:
            cand = self.waiting[0]
            if cand.boosted:
                victim_pool = [r for r in self.running if not r.boosted]
            else:
                victim_pool = self.running
            victims = [r for r in victim_pool
                       if getattr(r, "preempt_count", 0) < self.max_preemptions]
            if not victims:
                return
            victim = max(victims, key=self.policy.key)
            if (cand.boosted and not victim.boosted) or (
                    self.policy.key(cand) + self.preempt_margin
                    < self.policy.key(victim)):
                self.running.remove(victim)
                victim.state = RequestState.WAITING
                victim.preempt_count = getattr(victim, "preempt_count", 0) + 1
                # a half-prefilled victim loses its partial KV residency too:
                # re-admission re-prefills from offset 0 (recompute semantics)
                # and re-snapshots its prefill target
                victim.prefilled_tokens = 0
                victim.prefill_target = None
                if self.evict_hook is not None:
                    self.evict_hook(victim)
                self.waiting.append(victim)
                self._rank()
            else:
                return

    def retire_finished(self, now: float) -> List[Request]:
        done = [r for r in self.running if r.finished]
        for r in done:
            r.state = RequestState.FINISHED
            if r.finish_time is None:
                r.finish_time = now
        self.running = [r for r in self.running if not r.finished]
        return done

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)
