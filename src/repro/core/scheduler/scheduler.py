"""Predictor-guided continuous-batching scheduler (paper §III-B).

vLLM-style two-queue structure:

* **Waiting queue (W)** — arrived, not yet executing. Re-ranked every
  scheduling cycle by the policy's priority key (ascending).
* **Running queue (R)** — currently in the engine's batch, capacity
  ``max_batch``. Under continuous batching, finished requests are replaced
  at iteration granularity; under static batching a whole batch must drain
  before W is consulted again.

Starvation prevention (paper default 2 minutes): any waiting request whose
wait time exceeds ``starvation_threshold`` has its priority boosted — boosted
requests are scheduled ahead of everything else, FIFO among themselves.

**Iterative re-ranking** (:meth:`Scheduler.rerank`, driven by the serving
core's ``rerank_interval``): refresh every request's priority key to its
predicted *remaining* length through the policy's batched
:meth:`~repro.core.scheduler.policies.Policy.refresh`. The next scheduling
cycle's sort, admission order, and preemption victim choice all read the
refreshed keys — a long request that has nearly finished stops ranking as
"long". Because refreshed ranks can demote a request repeatedly, re-ranked
runs carry a starvation bound: a request preempted or deferred more than
``pin_after_demotions`` times is pinned boosted (scheduled ahead of all
ranked traffic, never preempted again).

This object is shared verbatim by the real JAX engine and the discrete-event
simulator; only the clock source differs.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

from repro.core.scheduler.policies import Policy
from repro.core.scheduler.request import Request, RequestState

DEFAULT_STARVATION_S = 120.0


@dataclass
class Scheduler:
    policy: Policy
    max_batch: int = 16
    starvation_threshold: float = DEFAULT_STARVATION_S
    continuous: bool = True            # False = static batching
    # vLLM-style recompute preemption (beyond-paper, off by default): when R
    # is full and a waiting request's priority key undercuts a running one by
    # more than ``preempt_margin``, the worst running request is evicted back
    # to W (losing its KV cache — on re-admission it re-prefills prompt +
    # already-generated tokens, which the simulator charges). Bounded per
    # request by ``max_preemptions`` to prevent thrash; boosted requests are
    # never preempted.
    preemption: bool = False
    preempt_margin: float = 0.0
    max_preemptions: int = 2
    # Starvation bound for refreshed ranks: once a request has been demoted
    # (preempted or deferred) more than this many times, it is pinned
    # boosted. ``None`` disables the bound (the historical behaviour); the
    # serving core sets it whenever iterative re-ranking is enabled.
    pin_after_demotions: Optional[int] = None
    # KV-budget awareness (installed by ServingCore): ``admit_hook`` is the
    # admission gate — called in rank order, it reserves cache blocks and
    # returns False to keep a request in W this cycle (memory back-pressure
    # without queue surgery). ``evict_hook`` releases a preemption victim's
    # reservation and backend residency. Both are optional so the scheduler
    # stays usable standalone in unit tests.
    admit_hook: Optional[Callable[[Request], bool]] = None
    evict_hook: Optional[Callable[[Request], None]] = None
    waiting: List[Request] = field(default_factory=list)
    running: List[Request] = field(default_factory=list)
    # observability: rank passes (full sorts of W) and re-rank refreshes —
    # the double-rank regression test counts the former per cycle
    rank_passes: int = 0
    rerank_count: int = 0
    # a refresh happened and no ranked cycle has consumed it yet: preemptions
    # in that first cycle are attributed to re-ranking (metrics)
    _just_reranked: bool = field(default=False, init=False, repr=False)

    # ------------------------------------------------------------------ API
    def add_request(self, req: Request) -> None:
        self.policy.annotate([req])
        req.state = RequestState.WAITING
        self.waiting.append(req)

    def add_requests(self, reqs: List[Request]) -> None:
        self.policy.annotate(reqs)
        for r in reqs:
            r.state = RequestState.WAITING
        self.waiting.extend(reqs)

    def rerank(self, now: float, *, floor: float = 0.0) -> int:
        """Refresh every queued request's priority key to its predicted
        remaining length (one batched scorer call for W — see
        ``Policy.refresh``). The following :meth:`schedule` cycle sorts,
        admits, and preempts by the refreshed keys. Returns the number of
        refreshed keys (0 for policies with no length estimate)."""
        n = self.policy.refresh(self.running, self.waiting, floor=floor)
        self.rerank_count += 1
        self._just_reranked = True
        return n

    def _boost(self, now: float) -> None:
        for r in self.waiting:
            if not r.boosted and now - r.arrival_time > self.starvation_threshold:
                r.boosted = True

    def _sort_key(self, r: Request) -> Tuple:
        """W ordering: boosted first (FIFO among them), then policy key,
        then arrival (stable tiebreak)."""
        return ((0, r.arrival_time, 0.0) if r.boosted
                else (1, self.policy.key(r), r.arrival_time))

    def _rank(self) -> None:
        self.waiting.sort(key=self._sort_key)
        self.rank_passes += 1

    def _note_demotion(self, r: Request) -> None:
        """Starvation bound under re-ranking: a request demoted (preempted
        or deferred) more than ``pin_after_demotions`` times is pinned
        boosted — ahead of all ranked traffic, never preempted again."""
        if (self.pin_after_demotions is not None
                and r.preempt_count + r.defer_count > self.pin_after_demotions):
            r.boosted = True

    def schedule(self, now: float) -> List[Request]:
        """One scheduling cycle: move top-ranked W → R up to capacity.

        Returns the newly admitted requests (engine must prefill them).
        Under static batching, admission only happens when R is empty.
        W is boosted and ranked exactly once per cycle; the preemption pass
        and the admission scan both reuse that one sort (victims evicted
        mid-cycle are inserted in rank order, not re-sorted).
        """
        self.retire_finished(now)
        if not self.continuous and self.running:
            return []
        free = self.max_batch - len(self.running)
        if not self.waiting or (free <= 0 and not self.preemption):
            return []
        # Predictor fault recovery: while a scorer dispatch has failed and
        # left waiting requests unscored (or the policy sits degraded), offer
        # the queue back for scoring each cycle. A healthy run never sets
        # ``needs_rescore``, so this line is dead on the fault-free path.
        if self.policy.needs_rescore:
            self.policy.rescore(self.waiting)
        self._boost(now)
        self._rank()
        if self.preemption:
            self._preempt()
            free = self.max_batch - len(self.running)
        self._just_reranked = False
        if free <= 0 or not self.waiting:
            return []
        if self.admit_hook is None:
            admitted = self.waiting[:free]
            del self.waiting[:free]
        else:
            admitted, kept = [], []
            for i, r in enumerate(self.waiting):
                if len(admitted) == free:
                    kept.extend(self.waiting[i:])
                    break
                (admitted if self.admit_hook(r) else kept).append(r)
            self.waiting = kept
        for r in admitted:
            r.state = RequestState.RUNNING
            r.start_time = now
        self.running.extend(admitted)
        return admitted

    def add_admit_gate(self, gate: Callable[[Request], bool]) -> None:
        """Compose an extra admission predicate with the installed
        ``admit_hook``. Admission then requires every gate *and* the base
        hook to accept; a gate returning False keeps the request in W this
        cycle, exactly like the hook itself. Gates added later run FIRST —
        cheap predicates evaluate before the serving core's hook reserves
        KV blocks, so a gate rejection can never leak a reservation. This
        is how a front-end above the core (the multi-replica router) vetoes
        or observes per-replica admissions through the same admission path
        instead of inventing a second gate mechanism."""
        base = self.admit_hook
        if base is None:
            self.admit_hook = gate
        else:
            def chained(r: Request, _gate=gate, _base=base) -> bool:
                return _gate(r) and _base(r)
            self.admit_hook = chained

    def defer(self, reqs: List[Request]) -> None:
        """Return admitted-but-unplaceable requests to the head of W (engine
        back-pressure through the scheduler API, not queue surgery). The
        caller is responsible for releasing any resources it reserved.

        Membership is by request *identity* (an id-set, O(n+m)): two
        field-identical requests must never be confused, and a linear
        ``r in reqs`` scan per running request was O(n·m)."""
        if not reqs:
            return
        ids = {id(r) for r in reqs}
        self.running = [r for r in self.running if id(r) not in ids]
        for r in reqs:
            r.state = RequestState.WAITING
            r.prefilled_tokens = 0       # deferred residency is fully released
            r.prefill_target = None
            r.defer_count += 1
            self._note_demotion(r)
        self.waiting[:0] = reqs

    def _preempt(self) -> None:
        """Evict worst-running in favour of strictly-better waiting requests
        (requires self.waiting already ranked; keeps it ranked)."""
        while len(self.running) >= self.max_batch and self.waiting:
            cand = self.waiting[0]
            # boosted requests are never preempted (the starvation bound's
            # "pinned" guarantee), whatever the candidate's key says
            victim_pool = [r for r in self.running if not r.boosted]
            victims = [r for r in victim_pool
                       if r.preempt_count < self.max_preemptions]
            if not victims:
                return
            victim = max(victims, key=self.policy.key)
            if (cand.boosted and not victim.boosted) or (
                    self.policy.key(cand) + self.preempt_margin
                    < self.policy.key(victim)):
                self.running.remove(victim)
                victim.state = RequestState.WAITING
                victim.preempt_count += 1
                if getattr(self, "_just_reranked", False):
                    victim.rerank_preemptions = \
                        (victim.rerank_preemptions or 0) + 1
                self._note_demotion(victim)
                # a half-prefilled victim loses its partial KV residency too:
                # re-admission re-prefills from offset 0 (recompute semantics)
                # and re-snapshots its prefill target
                victim.prefilled_tokens = 0
                victim.prefill_target = None
                if self.evict_hook is not None:
                    self.evict_hook(victim)
                # W stays sorted: insert at the victim's rank position
                # instead of re-sorting the whole queue
                bisect.insort(self.waiting, victim, key=self._sort_key)
            else:
                return

    def retire_finished(self, now: float) -> List[Request]:
        done = [r for r in self.running if r.finished]
        for r in done:
            r.state = RequestState.FINISHED
            if r.finish_time is None:
                r.finish_time = now
        self.running = [r for r in self.running if not r.finished]
        return done

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)
