"""Request lifecycle shared by the real engine and the simulator."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclass
class Request:
    req_id: int
    prompt: str
    arrival_time: float
    prompt_len: int                   # prefill tokens
    true_length: int                  # ground-truth decode tokens (completion)
    score: float = 0.0                # predictor score (higher = longer)
    state: RequestState = RequestState.WAITING
    # runtime bookkeeping
    start_time: Optional[float] = None        # admitted to running queue
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    tokens_done: int = 0
    boosted: bool = False                     # starvation-prevention flag
    preempt_count: int = 0                    # recompute-preemption evictions

    @property
    def finished(self) -> bool:
        return self.tokens_done >= self.true_length

    def per_token_latency(self) -> float:
        """End-to-end latency / output length (the paper's metric, §IV)."""
        assert self.finish_time is not None
        return (self.finish_time - self.arrival_time) / max(self.true_length, 1)
