"""Request lifecycle shared by the real engine and the simulator."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"
    # Terminal non-success states (fault tolerance): a dropped request left
    # the system without completing and will never re-enter it. Which one
    # is recorded in ``Request.drop_reason`` too, for metrics.
    CANCELLED = "cancelled"   # deadline expired (admission or in-flight)
    SHED = "shed"             # load shedding under sustained overload
    REJECTED = "rejected"     # KV demand can never fit the cache budget
    FAILED = "failed"         # replica-failover retry budget exhausted


#: States a request never leaves.
TERMINAL_STATES = frozenset({RequestState.FINISHED, RequestState.CANCELLED,
                             RequestState.SHED, RequestState.REJECTED,
                             RequestState.FAILED})


@dataclass(eq=False)
class Request:
    """One request's lifecycle record.

    ``eq=False`` is deliberate: requests are *identities*, not values. Two
    same-prompt arrivals in the same tick are field-identical, and dataclass
    value equality made queue membership tests (``Scheduler.defer``'s
    ``r not in reqs``, ``running.remove(victim)``) silently drop or evict
    the wrong one. Identity equality (and identity hashing) makes every
    list/set operation on queues refer to *this* request only.
    """
    req_id: int
    prompt: str
    arrival_time: float
    prompt_len: int                   # prefill tokens
    true_length: int                  # ground-truth decode tokens (completion)
    score: float = 0.0                # predicted total output length
    # Whether a policy scorer has annotated ``score``. An explicit flag, not
    # a ``score == 0.0`` sentinel: a legitimate predictor score of exactly
    # 0.0 must not look "unscored" and be re-scored on every add_requests.
    scored: bool = False
    # Iterative re-ranking (``rerank_interval`` on the serving core): the
    # priority key refreshed at the last re-rank, ``max(score − tokens_done,
    # floor)`` — predicted decode tokens *remaining*, not total. ``None``
    # means the write-once world: policies fall back to the arrival-time
    # score (or true length, for the oracle) exactly as before.
    remaining_est: Optional[float] = None
    state: RequestState = RequestState.WAITING
    # runtime bookkeeping
    start_time: Optional[float] = None        # admitted to running queue
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    tokens_done: int = 0
    # Chunked prefill: prompt tokens already resident in the KV cache, in the
    # *backend's* prompt-token space (``ExecutionBackend.prefill_total``; the
    # real engine pads prompts to its token bucket, the simulator uses
    # ``prompt_len``). A request only joins the decode batch once
    # ``prefilled_tokens >= prefill_total``. Reset to 0 on preemption
    # eviction — recompute semantics re-prefill from offset 0.
    prefilled_tokens: int = 0
    # Snapshot of ``prefill_total`` taken by the core at admission, so a
    # total that folds in recompute work (the simulator charges prompt +
    # already-generated tokens after preemption) stays frozen while the
    # request is resident instead of drifting as ``tokens_done`` grows.
    prefill_target: Optional[int] = None
    # Prefix caching: prompt tokens this request reused from the KV prefix
    # cache instead of re-prefilling, accumulated across (re-)admissions.
    # ``None`` means the serving core ran with caching disabled — metrics
    # report NaN rather than a misleading 0% hit rate; 0 is a true miss.
    cached_prefix_tokens: Optional[int] = None
    # Multi-tenant SLO workloads (repro.serving.workloads): the tenant the
    # request belongs to, its priority-class name, a numeric priority
    # (higher = more important — overload shedding takes low-priority
    # victims first and exempts priority > 0 from the predicted-length
    # admission gate), and the class's latency SLO targets: TTFT
    # (arrival → first token) and mean inter-token gap, in seconds. All
    # optional — a request without them schedules exactly as before
    # (priority 0, no SLO) and SLO metrics report NaN.
    tenant: Optional[str] = None
    priority_class: Optional[str] = None
    priority: int = 0
    slo_ttft_s: Optional[float] = None
    slo_itl_s: Optional[float] = None
    boosted: bool = False                     # starvation-prevention flag
    preempt_count: int = 0                    # recompute-preemption evictions
    defer_count: int = 0                      # engine back-pressure deferrals
    # Fault tolerance. ``deadline``: absolute completion deadline in the
    # serving clock's timebase; the core cancels the request (terminal
    # CANCELLED) the moment the deadline passes — at admission or mid-flight
    # — and sheds it at admission when the current length estimate says it
    # can never be met. ``None`` = no deadline (the historical behaviour).
    deadline: Optional[float] = None
    # Times this request was failed over after a replica crash (its KV was
    # lost; it re-dispatched with recompute-from-prompt). ``None`` means the
    # run had no failover layer — metrics report NaN instead of 0.
    failovers: Optional[int] = None
    # Earliest time the router may re-dispatch this request (failover
    # backoff: ``backoff * 2**(failovers-1)`` after the crash).
    route_after: Optional[float] = None
    # KV admission-gate rejections while waiting (cumulative across
    # replicas); the router's affinity-starvation escape compares this
    # against its value at routing time.
    gate_rejections: int = 0
    # Why a dropped request left the system ("deadline", "overload",
    # "kv-infeasible", "failover-budget"); None for live/finished requests.
    drop_reason: Optional[str] = None
    # Preemptions suffered in a scheduling cycle whose ranks had just been
    # refreshed by iterative re-ranking. ``None`` means the run never
    # re-ranked — metrics report NaN instead of a misleading 0.
    rerank_preemptions: Optional[int] = None
    # Incremental KV reservation (``kv_reservation="incremental"`` on the
    # serving core): decode-time block-``grow`` denials charged while *this*
    # request was trying to take its next decode step, and the number of
    # times this request was preempted to free blocks for another request's
    # grow. ``None`` means the run reserved full demand at admission — the
    # metrics layer reports NaN instead of a misleading 0.
    grow_failures: Optional[int] = None
    grow_preemptions: Optional[int] = None
    # Per-token completion timestamps (only filled when the serving core is
    # created with ``record_token_times=True``): one entry per generated
    # token, so inter-token-latency percentiles can be computed from actual
    # gaps instead of the (finish-first)/n mean.
    token_times: list = field(default_factory=list)
    # Generated token ids (real engine only, gated by ``record_tokens``):
    # used to check chunked and unchunked serving emit identical outputs.
    generated_tokens: list = field(default_factory=list)

    @property
    def finished(self) -> bool:
        return self.tokens_done >= self.true_length

    def per_token_latency(self) -> float:
        """End-to-end latency / output length (the paper's metric, §IV)."""
        assert self.finish_time is not None
        return (self.finish_time - self.arrival_time) / max(self.true_length, 1)
