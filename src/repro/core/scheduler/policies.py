"""Scheduling policies (paper §IV): FCFS, Pointwise/Listwise/Oracle SJF, PARS.

Every SJF-family policy is "sort the waiting queue by a score, ascending"
(shorter expected response first); they differ only in the score source:

* ``oracle``    — ground-truth response length (perfect foresight bound)
* ``pars``      — pairwise-margin-trained predictor score
* ``pointwise`` — L1-regression predictor score
* ``listwise``  — ListMLE-trained predictor score
* ``fcfs``      — arrival time (the vLLM default / baseline)

Predictor-backed policies are constructed with a ``RankingPredictor`` (or any
``score(prompts) -> array``) and annotate requests once on arrival — scoring
is O(1) per request at scheduling time (paper: "minimal overhead").

**Annotate vs refresh.** ``annotate`` is the write-once arrival path: score
every not-yet-scored request in one batched scorer call and never touch it
again (idempotent — an explicit ``Request.scored`` flag, not a score-value
sentinel). ``refresh`` is the iterative re-ranking path (ELIS-style, driven
by the serving core's ``rerank_interval``): re-score the *waiting* queue in
one batched call (so an online-updated predictor is picked up with zero
per-request dispatch) and refresh every request's priority key to its
predicted *remaining* length, ``max(estimate − tokens_done, floor)``, stored
in ``Request.remaining_est``. Keys read ``remaining_est`` when it has been
refreshed and fall back to the arrival-time basis otherwise, so a run that
never calls ``refresh`` behaves exactly as the historical write-once ranker.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

from repro.core.scheduler.request import Request

POLICY_NAMES = ("fcfs", "pars", "pars+", "pointwise", "listwise", "oracle")


@dataclass
class Policy:
    """Priority-key provider. Lower key = scheduled earlier.

    ``estimate`` maps a request to its predicted *total* output length — the
    basis ``refresh`` turns into a remaining-length key. ``None`` (fcfs)
    means the policy has no length estimate and ``refresh`` leaves its keys
    alone.
    """
    name: str
    key_fn: Callable[[Request], float]
    scorer: Optional[Callable[[Sequence[str]], "object"]] = None
    estimate: Optional[Callable[[Request], float]] = None

    def annotate(self, requests: List[Request]) -> None:
        """Attach predictor scores to newly arrived requests (batched).

        Idempotent: only requests never scored before are sent to the
        scorer, tracked by ``Request.scored`` — a legitimate score of
        exactly 0.0 is *not* re-scored on later ``add_requests`` calls.
        """
        if self.scorer is None:
            return
        todo = [r for r in requests if not r.scored]
        if not todo:
            return
        scores = self.scorer([r.prompt for r in todo])
        for r, s in zip(todo, scores):
            r.score = float(s)
            r.scored = True

    def refresh(self, running: Sequence[Request], waiting: Sequence[Request],
                *, floor: float = 0.0) -> int:
        """One iterative re-rank: refresh priority keys to predicted
        *remaining* length.

        Waiting requests are re-scored in a single batched scorer call
        (amortized — never one dispatch per request), then every request in
        both queues gets ``remaining_est = max(estimate − tokens_done,
        floor)``. Running requests are *not* re-scored (their prompt hasn't
        changed; their key shrinks because ``tokens_done`` grew). Returns
        the number of requests whose key was refreshed; 0 for policies with
        no length estimate (fcfs), whose keys never change.
        """
        if self.estimate is None:
            return 0
        if self.scorer is not None and waiting:
            scores = self.scorer([r.prompt for r in waiting])
            for r, s in zip(waiting, scores):
                r.score = float(s)
                r.scored = True
        n = 0
        for r in (*running, *waiting):
            r.remaining_est = max(self.estimate(r) - r.tokens_done, floor)
            n += 1
        return n

    def key(self, req: Request) -> float:
        return self.key_fn(req)


def fcfs() -> Policy:
    return Policy("fcfs", key_fn=lambda r: r.arrival_time)


def oracle_sjf() -> Policy:
    return Policy("oracle",
                  key_fn=lambda r: (r.remaining_est
                                    if r.remaining_est is not None
                                    else float(r.true_length)),
                  estimate=lambda r: float(r.true_length))


def predictor_sjf(name: str, scorer) -> Policy:
    """PARS / pointwise / listwise — SJF on predicted score (remaining
    length once refreshed)."""
    return Policy(name,
                  key_fn=lambda r: (r.remaining_est
                                    if r.remaining_est is not None
                                    else r.score),
                  scorer=scorer,
                  estimate=lambda r: r.score)


def pars_plus(scorer, *, alpha: float = 0.5, score_scale: float = 1.0) -> Policy:
    """Beyond-paper variant: prefill-aware SJF.

    The paper ranks by expected *decode* length only; at long-prompt regimes
    (prefill_32k-class requests) admission also pays a prefill cost ∝
    prompt_len. PARS+ ranks by

        key = score / score_scale + alpha * log1p(prompt_len)

    so two requests with equal expected decode length order by prefill cost.
    ``alpha=0`` reduces exactly to PARS. Under iterative re-ranking the
    decode term becomes the refreshed remaining length; the prefill term is
    a fixed property of the prompt and never decays. Evaluated in
    benchmarks/pars_plus_ablation.py.
    """
    import math

    def key(r: Request) -> float:
        base = r.remaining_est if r.remaining_est is not None else r.score
        return base / score_scale + alpha * math.log1p(r.prompt_len)
    return Policy("pars+", key_fn=key, scorer=scorer,
                  estimate=lambda r: r.score)


def make_policy(name: str, predictor=None, **kw) -> Policy:
    if name == "fcfs":
        return fcfs()
    if name == "oracle":
        return oracle_sjf()
    if name in ("pars", "pointwise", "listwise", "pars+"):
        assert predictor is not None, f"{name} needs a predictor"
        scorer = predictor.score if hasattr(predictor, "score") else predictor
        if name == "pars+":
            return pars_plus(scorer, **kw)
        return predictor_sjf(name, scorer)
    raise ValueError(f"unknown policy {name!r}")
