"""Scheduling policies (paper §IV): FCFS, Pointwise/Listwise/Oracle SJF, PARS.

Every SJF-family policy is "sort the waiting queue by a score, ascending"
(shorter expected response first); they differ only in the score source:

* ``oracle``    — ground-truth response length (perfect foresight bound)
* ``pars``      — pairwise-margin-trained predictor score
* ``pointwise`` — L1-regression predictor score
* ``listwise``  — ListMLE-trained predictor score
* ``fcfs``      — arrival time (the vLLM default / baseline)

Predictor-backed policies are constructed with a ``RankingPredictor`` (or any
``score(prompts) -> array``) and annotate requests once on arrival — scoring
is O(1) per request at scheduling time (paper: "minimal overhead").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional, Sequence

from repro.core.scheduler.request import Request

POLICY_NAMES = ("fcfs", "pars", "pars+", "pointwise", "listwise", "oracle")


@dataclass
class Policy:
    """Priority-key provider. Lower key = scheduled earlier."""
    name: str
    key_fn: Callable[[Request], float]
    scorer: Optional[Callable[[Sequence[str]], "object"]] = None

    def annotate(self, requests: List[Request]) -> None:
        """Attach predictor scores to newly arrived requests (batched)."""
        if self.scorer is None:
            return
        todo = [r for r in requests if r.score == 0.0]
        if not todo:
            return
        scores = self.scorer([r.prompt for r in todo])
        for r, s in zip(todo, scores):
            r.score = float(s)

    def key(self, req: Request) -> float:
        return self.key_fn(req)


def fcfs() -> Policy:
    return Policy("fcfs", key_fn=lambda r: r.arrival_time)


def oracle_sjf() -> Policy:
    return Policy("oracle", key_fn=lambda r: float(r.true_length))


def predictor_sjf(name: str, scorer) -> Policy:
    """PARS / pointwise / listwise — SJF on predicted score."""
    return Policy(name, key_fn=lambda r: r.score, scorer=scorer)


def pars_plus(scorer, *, alpha: float = 0.5, score_scale: float = 1.0) -> Policy:
    """Beyond-paper variant: prefill-aware SJF.

    The paper ranks by expected *decode* length only; at long-prompt regimes
    (prefill_32k-class requests) admission also pays a prefill cost ∝
    prompt_len. PARS+ ranks by

        key = score / score_scale + alpha * log1p(prompt_len)

    so two requests with equal expected decode length order by prefill cost.
    ``alpha=0`` reduces exactly to PARS. Evaluated in
    benchmarks/pars_plus_ablation.py.
    """
    import math

    def key(r: Request) -> float:
        return r.score / score_scale + alpha * math.log1p(r.prompt_len)
    return Policy("pars+", key_fn=key, scorer=scorer)


def make_policy(name: str, predictor=None, **kw) -> Policy:
    if name == "fcfs":
        return fcfs()
    if name == "oracle":
        return oracle_sjf()
    if name in ("pars", "pointwise", "listwise", "pars+"):
        assert predictor is not None, f"{name} needs a predictor"
        scorer = predictor.score if hasattr(predictor, "score") else predictor
        if name == "pars+":
            return pars_plus(scorer, **kw)
        return predictor_sjf(name, scorer)
    raise ValueError(f"unknown policy {name!r}")
