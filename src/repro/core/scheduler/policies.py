"""Scheduling policies (paper §IV): FCFS, Pointwise/Listwise/Oracle SJF, PARS.

Every SJF-family policy is "sort the waiting queue by a score, ascending"
(shorter expected response first); they differ only in the score source:

* ``oracle``    — ground-truth response length (perfect foresight bound)
* ``pars``      — pairwise-margin-trained predictor score
* ``pointwise`` — L1-regression predictor score
* ``listwise``  — ListMLE-trained predictor score
* ``fcfs``      — arrival time (the vLLM default / baseline)

Predictor-backed policies are constructed with a ``RankingPredictor`` (or any
``score(prompts) -> array``) and annotate requests once on arrival — scoring
is O(1) per request at scheduling time (paper: "minimal overhead").

**Annotate vs refresh.** ``annotate`` is the write-once arrival path: score
every not-yet-scored request in one batched scorer call and never touch it
again (idempotent — an explicit ``Request.scored`` flag, not a score-value
sentinel). ``refresh`` is the iterative re-ranking path (ELIS-style, driven
by the serving core's ``rerank_interval``): re-score the *waiting* queue in
one batched call (so an online-updated predictor is picked up with zero
per-request dispatch) and refresh every request's priority key to its
predicted *remaining* length, ``max(estimate − tokens_done, floor)``, stored
in ``Request.remaining_est``. Keys read ``remaining_est`` when it has been
refreshed and fall back to the arrival-time basis otherwise, so a run that
never calls ``refresh`` behaves exactly as the historical write-once ranker.

**Predictor graceful degradation.** A production scorer can die, return
garbage, or stall. Every scorer dispatch therefore goes through
:meth:`Policy._dispatch`, which converts exceptions (and wall-clock
overruns past ``scorer_timeout_s``) into counted failures instead of
crashing the scheduler. Requests in a failed batch stay unscored and rank
*last* (unknown length is treated as long — conservative for SJF) until a
retry scores them; after ``scorer_failure_budget`` consecutive dispatch
failures the policy **degrades to FCFS**: every key becomes the request's
arrival time, exactly the ladder proxy-model serving uses when the proxy is
unavailable. While degraded the policy keeps probing the scorer every
``recovery_probe_every``-th dispatch opportunity and recovers automatically
on the first success (keys revert to predictor ranks the next cycle). Both
transitions are counted (``degradations`` / ``recoveries``) and logged.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

from repro.core.scheduler.request import Request

POLICY_NAMES = ("fcfs", "pars", "pars+", "pointwise", "listwise", "oracle")

log = logging.getLogger(__name__)

# Rank basis for a request whose scoring dispatch failed (and the policy is
# not yet degraded): last, behind every scored request — unknown length is
# treated as long. The starvation boost still applies, so it cannot starve.
UNSCORED_KEY = float("inf")


@dataclass
class Policy:
    """Priority-key provider. Lower key = scheduled earlier.

    ``estimate`` maps a request to its predicted *total* output length — the
    basis ``refresh`` turns into a remaining-length key. ``None`` (fcfs)
    means the policy has no length estimate and ``refresh`` leaves its keys
    alone.

    ``scorer_failure_budget`` — consecutive failed scorer dispatches before
    the policy degrades to FCFS keys. ``scorer_timeout_s`` — wall-clock
    budget per dispatch; an overrun counts as a failure (the call's result
    is discarded, exactly as if the caller had timed it out).
    ``recovery_probe_every`` — while degraded, probe the scorer on every
    N-th dispatch opportunity; the first success recovers the policy.
    """
    name: str
    key_fn: Callable[[Request], float]
    scorer: Optional[Callable[[Sequence[str]], "object"]] = None
    estimate: Optional[Callable[[Request], float]] = None
    scorer_failure_budget: int = 3
    scorer_timeout_s: Optional[float] = None
    recovery_probe_every: int = 1
    # degradation state (observable, not configuration)
    degraded: bool = field(default=False, init=False)
    consecutive_failures: int = field(default=0, init=False)
    scorer_failures: int = field(default=0, init=False)
    degradations: int = field(default=0, init=False)
    recoveries: int = field(default=0, init=False)
    _probe_calls: int = field(default=0, init=False, repr=False)
    # a dispatch failed and some requests may still be unscored: the
    # scheduler re-offers the waiting queue via ``rescore`` until clear
    needs_rescore: bool = field(default=False, init=False)

    # ---------------------------------------------------------- fault ladder
    def _dispatch(self, prompts: Sequence[str]):
        """One guarded batched scorer call. Returns the scores, or ``None``
        on failure (exception or wall-clock timeout) — never raises. All
        degradation/recovery bookkeeping lives here, so every dispatch site
        (annotate / refresh / probe) shares one ladder."""
        if self.degraded:
            self._probe_calls += 1
            if self._probe_calls % max(self.recovery_probe_every, 1):
                return None             # not this opportunity: stay degraded
        t0 = time.perf_counter() if self.scorer_timeout_s is not None else 0.0
        try:
            scores = self.scorer(prompts)
        except Exception as e:          # noqa: BLE001 — any scorer fault
            return self._note_failure(repr(e))
        if (self.scorer_timeout_s is not None
                and time.perf_counter() - t0 > self.scorer_timeout_s):
            return self._note_failure(
                f"dispatch exceeded {self.scorer_timeout_s}s")
        self.consecutive_failures = 0
        if self.degraded:
            self.degraded = False
            self.recoveries += 1
            log.warning("policy %s: scorer healed — restoring %s ranking",
                        self.name, self.name)
        return scores

    def _note_failure(self, why: str):
        self.scorer_failures += 1
        self.consecutive_failures += 1
        self.needs_rescore = True
        if (not self.degraded
                and self.consecutive_failures >= self.scorer_failure_budget):
            self.degraded = True
            self.degradations += 1
            log.warning("policy %s: %d consecutive scorer failures (last: "
                        "%s) — degrading to FCFS keys", self.name,
                        self.consecutive_failures, why)
        return None

    def annotate(self, requests: List[Request]) -> None:
        """Attach predictor scores to newly arrived requests (batched).

        Idempotent: only requests never scored before are sent to the
        scorer, tracked by ``Request.scored`` — a legitimate score of
        exactly 0.0 is *not* re-scored on later ``add_requests`` calls.
        A failed dispatch leaves its batch unscored (ranked last) and
        flags ``needs_rescore`` so the scheduler retries next cycle.
        """
        if self.scorer is None:
            return
        todo = [r for r in requests if not r.scored]
        if not todo:
            return
        scores = self._dispatch([r.prompt for r in todo])
        if scores is None:
            return
        for r, s in zip(todo, scores):
            r.score = float(s)
            r.scored = True

    def rescore(self, waiting: Sequence[Request]) -> None:
        """Retry path, called by the scheduler while ``needs_rescore``:
        score every still-unscored waiting request, or — when degraded with
        nothing left to score — probe the scorer with one live prompt so
        recovery does not depend on fresh arrivals."""
        todo = [r for r in waiting if not r.scored]
        if todo:
            self.annotate(todo)
            return
        if self.degraded:
            if waiting:
                self._dispatch([waiting[0].prompt])   # recovery probe
        else:
            self.needs_rescore = False               # everything scored

    def refresh(self, running: Sequence[Request], waiting: Sequence[Request],
                *, floor: float = 0.0) -> int:
        """One iterative re-rank: refresh priority keys to predicted
        *remaining* length.

        Waiting requests are re-scored in a single batched scorer call
        (amortized — never one dispatch per request), then every request in
        both queues gets ``remaining_est = max(estimate − tokens_done,
        floor)``. Running requests are *not* re-scored (their prompt hasn't
        changed; their key shrinks because ``tokens_done`` grew). Returns
        the number of requests whose key was refreshed; 0 for policies with
        no length estimate (fcfs), whose keys never change.

        A failed (or degraded) scorer dispatch skips the re-score: keys are
        still decayed by ``tokens_done`` below — stale-but-decaying ranks,
        exactly ELIS's tolerance for a broken estimator.
        """
        if self.estimate is None:
            return 0
        if self.scorer is not None and waiting:
            scores = self._dispatch([r.prompt for r in waiting])
            if scores is not None:
                for r, s in zip(waiting, scores):
                    r.score = float(s)
                    r.scored = True
        n = 0
        for r in (*running, *waiting):
            r.remaining_est = max(self.estimate(r) - r.tokens_done, floor)
            n += 1
        return n

    def key(self, req: Request) -> float:
        if self.scorer is not None:
            if self.degraded:
                return req.arrival_time          # FCFS fallback, all requests
            if not req.scored and self.needs_rescore:
                # a dispatch failure left this request unscored: rank last
                # (unknown length reads as long) until the retry scores it.
                # Gated on the outstanding-failure flag so hand-scored
                # requests outside the serving flow keep their key_fn rank.
                return UNSCORED_KEY
        return self.key_fn(req)


def fcfs() -> Policy:
    return Policy("fcfs", key_fn=lambda r: r.arrival_time)


def oracle_sjf() -> Policy:
    return Policy("oracle",
                  key_fn=lambda r: (r.remaining_est
                                    if r.remaining_est is not None
                                    else float(r.true_length)),
                  estimate=lambda r: float(r.true_length))


def predictor_sjf(name: str, scorer, **fault_kw) -> Policy:
    """PARS / pointwise / listwise — SJF on predicted score (remaining
    length once refreshed). ``fault_kw`` forwards the degradation knobs
    (``scorer_failure_budget`` / ``scorer_timeout_s`` /
    ``recovery_probe_every``)."""
    return Policy(name,
                  key_fn=lambda r: (r.remaining_est
                                    if r.remaining_est is not None
                                    else r.score),
                  scorer=scorer,
                  estimate=lambda r: r.score,
                  **fault_kw)


def pars_plus(scorer, *, alpha: float = 0.5, score_scale: float = 1.0,
              **fault_kw) -> Policy:
    """Beyond-paper variant: prefill-aware SJF.

    The paper ranks by expected *decode* length only; at long-prompt regimes
    (prefill_32k-class requests) admission also pays a prefill cost ∝
    prompt_len. PARS+ ranks by

        key = score / score_scale + alpha * log1p(prompt_len)

    so two requests with equal expected decode length order by prefill cost.
    ``alpha=0`` reduces exactly to PARS. Under iterative re-ranking the
    decode term becomes the refreshed remaining length; the prefill term is
    a fixed property of the prompt and never decays. Evaluated in
    benchmarks/pars_plus_ablation.py.
    """
    import math

    def key(r: Request) -> float:
        base = r.remaining_est if r.remaining_est is not None else r.score
        return base / score_scale + alpha * math.log1p(r.prompt_len)
    return Policy("pars+", key_fn=key, scorer=scorer,
                  estimate=lambda r: r.score, **fault_kw)


def make_policy(name: str, predictor=None, **kw) -> Policy:
    if name == "fcfs":
        return fcfs()
    if name == "oracle":
        return oracle_sjf()
    if name in ("pars", "pointwise", "listwise", "pars+"):
        assert predictor is not None, f"{name} needs a predictor"
        scorer = predictor.score if hasattr(predictor, "score") else predictor
        if name == "pars+":
            return pars_plus(scorer, **kw)
        return predictor_sjf(name, scorer, **kw)
    raise ValueError(f"unknown policy {name!r}")
