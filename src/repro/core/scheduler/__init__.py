"""Predictor-guided scheduler (paper §III-B): policies + W/R queue engine."""
from repro.core.scheduler.policies import POLICY_NAMES, Policy, fcfs, make_policy, oracle_sjf, predictor_sjf
from repro.core.scheduler.request import Request, RequestState
from repro.core.scheduler.scheduler import DEFAULT_STARVATION_S, Scheduler
