"""Workload construction: arrival processes + Request materialization."""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.scheduler.request import Request
from repro.data.synthetic import Corpus, prompt_lengths


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """n arrival times with exponential inter-arrival gaps (req/s)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, n)
    return np.cumsum(gaps)


def burst_arrivals(n: int) -> np.ndarray:
    """Paper §IV-D burst scenario: n simultaneous requests at t=0."""
    return np.zeros(n)


def make_requests(corpus: Corpus, lengths: Sequence[int],
                  arrivals: Sequence[float],
                  indices: Optional[Sequence[int]] = None) -> List[Request]:
    """Materialize Requests from corpus rows (optionally a subset)."""
    idx = list(indices) if indices is not None else list(range(len(arrivals)))
    plens = prompt_lengths([corpus.prompts[j] for j in idx])
    return [
        Request(req_id=i,
                prompt=corpus.prompts[j],
                arrival_time=float(arrivals[i]),
                prompt_len=int(plens[i]),
                true_length=int(lengths[j]))
        for i, j in enumerate(idx)
    ]
