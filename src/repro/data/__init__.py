"""Data pipeline: synthetic corpus/length oracle + workload arrival processes."""
from repro.data.synthetic import (DATASETS, MODELS, Corpus, EXAMPLE_PROMPTS,
                                  LLMProfile, make_corpus, prompt_lengths, sample_lengths)
