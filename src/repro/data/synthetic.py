"""Synthetic prompt corpus + response-length oracle.

Offline stand-in for Alpaca / LMSYS-Chat-1M prompts and GPT-4 / Llama-3.1 /
DeepSeek-R1 response lengths (DESIGN.md §8). The generators are calibrated to
the paper's observed regimes:

* Prompt *complexity* z is a linear function of visible lexical features
  (task verb + topic weights + prompt length) plus prompt-level irreducible
  noise — so a text predictor can learn z, but not perfectly.
* Response length  L = round(exp(base + slope·z + hidden + run_noise)):
  - ``run_noise`` gives the ~20% (instruct) / ~25% (reasoning) max/min
    run-to-run relative variance of paper Fig. 2 (σ=0.06 / 0.075 lognormal);
  - ``hidden`` is per-(prompt, model) latent difficulty invisible in the
    text — it sets the τ_b ceiling (small for the GPT-4-like generator,
    large for the R1-like one, matching Table II's ordering);
  - reasoning models include the CoT trace in L (paper §IV-A), hence the
    large base and occasional multi-thousand-token outputs (Table I).
* Datasets: "alpaca" (clean instructions) vs "lmsys" (noisier, more filler,
  extra hidden noise) — reproducing the Alpaca > LMSYS accuracy gap.

Everything is seeded and deterministic given (dataset, model, seed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Emulated target LLMs (the paper's three)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LLMProfile:
    name: str
    reasoning: bool
    base: float          # log-length intercept
    slope: float         # complexity sensitivity
    run_sigma: float     # run-to-run lognormal noise (Fig. 2 regime)
    hidden_sigma: float  # per-(prompt,model) latent noise → τ ceiling
    delta: float         # paper's min_length_difference threshold for this LLM


MODELS: Dict[str, LLMProfile] = {
    # instruct-class: short, highly prompt-determined outputs
    "gpt4":  LLMProfile("gpt4",  False, base=2.9, slope=1.00, run_sigma=0.06,
                        hidden_sigma=0.10, delta=0.20),
    "llama": LLMProfile("llama", False, base=2.6, slope=0.90, run_sigma=0.06,
                        hidden_sigma=0.45, delta=0.20),
    # reasoning-class: CoT trace included in length; long + weakly predictable
    "r1":    LLMProfile("r1",    True,  base=6.1, slope=0.75, run_sigma=0.075,
                        hidden_sigma=0.80, delta=0.25),
}

DATASETS = ("alpaca", "lmsys")

# Task verbs with complexity weights (reasoning-heavy verbs → long outputs).
_VERBS = [
    ("what is", -1.2), ("define", -1.0), ("name", -1.3), ("count", -0.8),
    ("translate", -0.5), ("classify", -0.6), ("summarize", 0.1),
    ("list", 0.2), ("describe", 0.4), ("explain", 0.8), ("compare", 0.9),
    ("analyze", 1.1), ("write an essay about", 1.4), ("write code for", 1.2),
    ("prove", 1.6), ("derive", 1.7), ("design a plan for", 1.3),
    ("walk me through", 1.0), ("debate", 1.2), ("brainstorm ideas about", 0.9),
]
_FILLER = ("please could you kindly just quickly briefly the a an of in on "
           "for with about regarding concerning my our this that").split()
N_TOPICS = 240


@dataclass
class Corpus:
    dataset: str
    prompts: List[str]
    z: np.ndarray                      # latent complexity per prompt
    seed: int


def _topic_weights(seed: int = 1234) -> np.ndarray:
    return np.random.default_rng(seed).normal(0.0, 1.0, N_TOPICS)


def make_corpus(dataset: str, n: int, seed: int = 0) -> Corpus:
    assert dataset in DATASETS
    rng = np.random.default_rng(seed + (0 if dataset == "alpaca" else 10_000))
    tw = _topic_weights()
    prompts, zs = [], []
    messy = dataset == "lmsys"
    for _ in range(n):
        vi = rng.integers(len(_VERBS))
        ti = rng.integers(N_TOPICS)
        verb, wv = _VERBS[vi]
        n_fill = rng.integers(0, 12 if messy else 5)
        fillers = list(rng.choice(_FILLER, n_fill))
        extra = []
        extra_w = 0.0
        if rng.random() < 0.45:                          # secondary topic
            t2 = rng.integers(N_TOPICS)
            extra = [f"topic{t2}"]
            extra_w = 0.35 * tw[t2]
        words = [verb, f"topic{ti}"] + extra + fillers
        rng.shuffle(words)
        # keep verb first for readability ~half the time
        prompt = verb + " " + " ".join(w for w in words if w != verb)
        z = (1.0 * wv + 0.6 * tw[ti] + extra_w
             + 0.04 * len(prompt.split())
             + rng.normal(0.0, 0.35 if messy else 0.2))  # irreducible
        prompts.append(prompt)
        zs.append(z)
    return Corpus(dataset, prompts, np.asarray(zs, np.float64), seed)


def sample_lengths(corpus: Corpus, model: str, *, run_seed: int = 0,
                   n_runs: int = 1) -> np.ndarray:
    """Ground-truth output lengths. (n,) if n_runs==1 else (n_runs, n).

    The per-(prompt, model) hidden component is drawn from a seed independent
    of ``run_seed`` — repeated runs share it (only run_noise varies), exactly
    like re-querying the same LLM (paper Fig. 2).
    """
    prof = MODELS[model]
    n = len(corpus.prompts)
    hidden_rng = np.random.default_rng(
        hash((corpus.dataset, corpus.seed, model)) % 2**32)
    extra = 0.25 if corpus.dataset == "lmsys" else 0.0
    hidden = hidden_rng.normal(0.0, prof.hidden_sigma + extra, n)
    mu = prof.base + prof.slope * corpus.z + hidden
    # reasoning models "overthink" some prompts (heavy right tail, Table I);
    # which prompts is a latent property, stable across runs (paper Fig. 2
    # bounds the *run-to-run* variance to ~25%)
    if prof.reasoning:
        spike = hidden_rng.random(n) < 0.08
        mu = mu + spike * np.log(hidden_rng.integers(2, 5, n))
    run_rng = np.random.default_rng(run_seed + 777)
    noise = run_rng.normal(0.0, prof.run_sigma, (n_runs, n))
    lengths = np.maximum(1, np.round(np.exp(mu[None] + noise))).astype(np.int64)
    return lengths[0] if n_runs == 1 else lengths


def prompt_lengths(prompts: Sequence[str]) -> np.ndarray:
    """Token counts of the prompts themselves (for prefill cost models)."""
    return np.asarray([len(p.split()) for p in prompts], np.int64)


# Table-I style demo prompts (fixed low/high complexity)
EXAMPLE_PROMPTS = {
    "Q1": 'count topic7',                    # "How many r in strawberry"-like
    "Q2": 'prove topic42 derive topic42',    # multi-step math-like
}
