"""Pallas TPU kernel: causal GQA flash attention (prefill / training).

TPU adaptation (DESIGN.md §4): rather than porting the CUDA warp layout, the
kernel tiles for VMEM and the MXU —

* grid = (batch, q_heads, Sq/block_q, Skv/block_k); the KV axis is the
  innermost, *sequential* ("arbitrary") dimension so the online-softmax
  scratch accumulators persist across KV tiles in VMEM.
* BlockSpecs stage (block_q × dh) Q tiles and (block_k × dh) K/V tiles
  HBM→VMEM; block sizes default to 128 so the MXU sees 128-aligned matmuls.
* GQA is expressed in the K/V index_map (kv_head = q_head // q_per_kv) — no
  materialized head broadcast.
* Softmax statistics (m, l) and the output accumulator are f32 VMEM scratch.
* Fully-masked causal tiles are skipped with pl.when (upper-triangle pruning).

Validated in interpret mode against ``ref.py`` (this container is CPU-only;
TPU is the compile target).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, seq_q: int,
                  seq_k: int, causal: bool, window: Optional[int],
                  n_kv_blocks: int):
    i = pl.program_id(2)      # q block
    j = pl.program_id(3)      # kv block (sequential)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal pruning: this tile contributes iff some k_idx <= some q_idx
    live = jnp.asarray(True)
    if causal:
        live = j * block_k <= i * block_q + block_q - 1
    if window is not None:
        # tile dead if even the newest k is older than the oldest q's window
        live = jnp.logical_and(
            live, i * block_q - (j * block_k + block_k - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (block_q, dh)
        k = k_ref[0, 0].astype(jnp.float32)            # (block_k, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        q_idx = i * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_idx = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = (k_idx < seq_k) & (q_idx < seq_q)
        if causal:
            mask &= k_idx <= q_idx
        if window is not None:
            mask &= q_idx - k_idx < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(p, v)
        m_scr[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  block_q: int = 128, block_k: int = 128,
                  true_q: Optional[int] = None, true_k: Optional[int] = None,
                  interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, dh); k/v: (B, KH, Skv, dh) → (B, H, Sq, dh).

    Sq/Skv must be multiples of the block sizes (ops.py pads).
    """
    b, h, sq, dh = q.shape
    kh, skv = k.shape[1], k.shape[2]
    qpk = h // kh
    n_q, n_k = sq // block_q, skv // block_k
    grid = (b, h, n_q, n_k)

    kernel = functools.partial(
        _flash_kernel, scale=dh ** -0.5, block_q=block_q, block_k=block_k,
        seq_q=true_q or sq, seq_k=true_k or skv, causal=causal,
        window=window, n_kv_blocks=n_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b_, h_, i, j: (b_, h_ // qpk, j, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b_, h_, i, j: (b_, h_ // qpk, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
