"""Pure-jnp oracle for the flash_prefill kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_prefill_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True,
                      window: Optional[int] = None) -> jax.Array:
    """q: (B, H, Sq, dh); k/v: (B, KH, Skv, dh) → (B, H, Sq, dh). f32 math."""
    b, h, sq, dh = q.shape
    kh, skv = k.shape[1], k.shape[2]
    qpk = h // kh
    k = jnp.repeat(k, qpk, axis=1)
    v = jnp.repeat(v, qpk, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    q_idx = jnp.arange(sq)[:, None]
    k_idx = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_idx <= q_idx
    if window is not None:
        mask &= q_idx - k_idx < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
