"""Jitted public wrapper for flash_prefill: padding + layout handling."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_prefill.kernel import flash_prefill


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """(B, H, Sq, dh) × (B, KH, Skv, dh) → (B, H, Sq, dh), padded to blocks."""
    sq, skv = q.shape[2], k.shape[2]
    bq = min(block_q, max(8, 1 << (sq - 1).bit_length()))
    bk = min(block_k, max(8, 1 << (skv - 1).bit_length()))
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    out = flash_prefill(qp, kp, vp, causal=causal, window=window,
                        block_q=bq, block_k=bk, true_q=sq, true_k=skv,
                        interpret=interpret)
    return out[:, :, :sq]
