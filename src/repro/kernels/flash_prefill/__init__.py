from repro.kernels.flash_prefill.kernel import *  # noqa
