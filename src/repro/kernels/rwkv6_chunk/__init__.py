from repro.kernels.rwkv6_chunk.kernel import *  # noqa
