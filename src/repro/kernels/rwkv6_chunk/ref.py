"""Pure-jnp oracle for rwkv6_chunk: token-by-token recurrence (no chunking).

Deliberately independent of the chunked algorithm — a direct lax.scan over
tokens implementing the published recurrences, so kernel and model-level
chunked math are both validated against first principles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_recurrent_ref(q, k, v, log_decay, bonus, *, mode: str = "rwkv"):
    """Same signature/shapes as the kernel; scans one token at a time."""
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    lw = jnp.broadcast_to(log_decay.astype(jnp.float32), (b, h, t, dk))
    uf = bonus.astype(jnp.float32)

    def step(state, xs):
        qt, kt, vt, lwt = xs                         # (B,H,dk|dv)
        outer = jnp.einsum("bhd,bhe->bhde", kt, vt)
        if mode == "rwkv":
            out = (jnp.einsum("bhd,bhde->bhe", qt, state)
                   + jnp.sum(qt * uf[None] * kt, -1, keepdims=True) * vt)
            state = state * jnp.exp(lwt)[..., None] + outer
        else:
            state = state * jnp.exp(lwt)[..., None] + outer
            out = jnp.einsum("bhd,bhde->bhe", qt, state)
        return state, out

    s0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    xs = tuple(x.transpose(2, 0, 1, 3) for x in (qf, kf, vf, lw))
    _, outs = jax.lax.scan(step, s0, xs)
    return outs.transpose(1, 2, 0, 3).astype(q.dtype)
