"""Pallas TPU kernel: chunked linear attention with data-dependent decay.

Serves both RWKV-6 time-mix (vector decay + bonus-u, ``mode="rwkv"``) and
Mamba-2/SSD (scalar decay broadcast to the k-dim, ``mode="ssd"``) — the same
recurrences as ``repro.models.linear_attn`` (the oracle).

TPU adaptation of the recurrent GPU kernel (DESIGN.md §4): instead of one
thread-block per head scanning tokens, the grid is
(batch, heads, T/chunk) with the chunk axis innermost and *sequential*; the
(dk × dv) state is f32 VMEM scratch carried across chunk steps. Each step does
three (C×d)·(d×C|d) MXU matmuls (intra-chunk attention, state read, state
update) on VMEM-resident tiles — chunk=64, d=64..128 keeps everything in a few
hundred KiB of VMEM.

NUMERICS CONTRACT (same as the oracle): per-step log-decay ∈ [-1, 0); with
chunk ≤ 80 the intra-chunk exponentials stay in f32 range.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(q_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_scr, *,
                 chunk: int, mode: str):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    qc = q_ref[0, 0].astype(jnp.float32)        # (C, dk)
    kc = k_ref[0, 0].astype(jnp.float32)
    vc = v_ref[0, 0].astype(jnp.float32)        # (C, dv)
    lw = lw_ref[0, 0].astype(jnp.float32)       # (C, dk)
    u = u_ref[0].astype(jnp.float32)            # (dk,)

    inc = jnp.cumsum(lw, axis=0)                # inclusive prefix Σ log w
    exc = inc - lw
    tot = inc[-1:, :]                           # (1, dk)

    q_dec = qc * jnp.exp(exc if mode == "rwkv" else inc)
    k_dec = kc * jnp.exp(-inc)
    k_tail = kc * jnp.exp(tot - inc)

    state = state_scr[...]                      # (dk, dv)
    inter = jnp.dot(q_dec, state)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)
    att = jax.lax.dot_general(q_dec, k_dec, (((1,), (1,)), ((), ()))) * tri
    diag = jnp.sum(qc * u[None, :] * kc, axis=-1, keepdims=True)
    out = inter + jnp.dot(att, vc) + diag * vc

    state_scr[...] = (state * jnp.exp(tot).T
                      + jax.lax.dot_general(k_tail, vc, (((0,), (0,)), ((), ()))))
    o_ref[0, 0] = out.astype(o_ref.dtype)


def rwkv6_chunk(q: jax.Array, k: jax.Array, v: jax.Array, log_decay: jax.Array,
                bonus: jax.Array, *, chunk: int = 64, mode: str = "rwkv",
                interpret: bool = True) -> jax.Array:
    """q/k/lw: (B, H, T, dk); v: (B, H, T, dv); bonus: (H, dk) → (B, H, T, dv).

    T must be a multiple of ``chunk`` (ops.py pads). For ``mode="ssd"`` pass
    ``bonus=ones`` (the diag term is (q·k) with no decay).
    """
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    n_c = t // chunk
    grid = (b, h, n_c)
    kernel = functools.partial(_rwkv_kernel, chunk=chunk, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dk), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, dk), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, dv), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, dk), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, dk), lambda b_, h_, c: (h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, dv), lambda b_, h_, c: (b_, h_, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, log_decay, bonus)
