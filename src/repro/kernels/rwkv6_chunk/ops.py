"""Jitted public wrapper for rwkv6_chunk (padding + scalar-decay broadcast)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_chunk.kernel import rwkv6_chunk


@functools.partial(jax.jit, static_argnames=("chunk", "mode", "interpret"))
def linear_attention_pallas(q, k, v, log_decay, bonus=None, *, chunk: int = 64,
                            mode: str = "rwkv", interpret: bool = True):
    """Drop-in twin of models.linear_attn.chunked_linear_attention (output
    only — state handoff stays in the XLA path). Pads T to the chunk size and
    broadcasts scalar SSD decay across the k-dim."""
    b, h, t, dk = q.shape
    lw = jnp.broadcast_to(log_decay, (b, h, t, dk))
    if bonus is None:
        bonus = jnp.ones((h, dk), jnp.float32)
    pad = (-t) % chunk
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(x, widths) for x in (q, k, v))
        lw = jnp.pad(lw, widths)
    out = rwkv6_chunk(q, k, v, lw, bonus, chunk=chunk, mode=mode,
                      interpret=interpret)
    return out[:, :, :t]
