"""Pallas TPU kernel: single-token GQA decode attention over a (ring) KV cache.

This is the serving hot-spot for ``decode_32k`` / ``long_500k``: one query
token per sequence against a KV cache of up to 512k entries. TPU adaptation:

* grid = (batch, kv_heads, W/block_k); the cache-length axis is innermost and
  sequential, carrying online-softmax scratch in VMEM.
* The q_per_kv query heads of one KV head are processed *together* as a
  (q_per_kv × dh) tile so the MXU gets a real matmul instead of a per-head
  vector dot (GQA head-grouping — the TPU analogue of the CUDA warp-per-head
  layout).
* Ring-buffer validity/window masking arrives as a precomputed additive f32
  bias vector (0 / -inf per slot), blocked alongside K — no scalar prefetch
  needed and the same kernel serves append and ring caches.

Memory: per grid step VMEM = block_k·dh (K) + block_k·dh (V) + q_per_kv·dh
tiles — with defaults (block_k=512, dh=128, bf16) ≈ 256 KiB, far under VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` → interpret only off-TPU, so the compiled kernel path is
    exercised wherever real hardware is present (CI containers are CPU-only
    and fall back to interpret mode automatically)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _decode_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, n_kv_blocks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)                 # (block_k, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    bias = bias_ref[0].astype(jnp.float32)              # (block_k,)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, bk)
    s = s + bias[None, :]

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(p, v)
    m_scr[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 bias: jax.Array, *, block_k: int = 512,
                 interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, KH, G, dh); caches: (B, KH, W, dh); bias: (B, W) → (B, KH, G, dh).

    ``bias`` is 0 for valid slots and ≤ NEG_INF for invalid/out-of-window
    slots (see ops.py). W must be a multiple of block_k (ops.py pads).
    """
    b, kh, g, dh = q.shape
    w = k_cache.shape[2]
    n_k = w // block_k
    grid = (b, kh, n_k)

    kernel = functools.partial(_decode_kernel, scale=dh ** -0.5,
                               n_kv_blocks=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda b_, h_, j: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, block_k), lambda b_, h_, j: (b_, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda b_, h_, j: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(q, k_cache, v_cache, bias)


def _paged_decode_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale: float,
                         block_size: int, max_blocks: int):
    """Block-table step: grid position (b, h, j) sees K/V block
    ``tbl_ref[b, j]`` of the global pool (the BlockSpec index map does the
    gather — the kernel body is the same online softmax as
    ``_decode_kernel`` with the validity mask computed in-kernel from the
    sequence length instead of a precomputed bias lane)."""
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)                 # (block_size, dh)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, bs)
    # token position of each row in this block; rows past the sequence
    # length are masked (covers both the ragged tail block and whole
    # padding blocks of a short table)
    pos = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)                  # (1, bs)
    s = jnp.where(pos < len_ref[b], s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(p, v)
    m_scr[...] = m_new

    @pl.when(j == max_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_decode_paged(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                       block_tables: jax.Array, lengths: jax.Array, *,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Paged decode attention: K/V gathered through per-sequence block tables.

    q: (B, KH, G, dh); pools: (num_blocks, KH, block_size, dh);
    block_tables: (B, max_blocks) int32 — physical pool block of each
    logical block (entries past the sequence's last block may point
    anywhere valid, e.g. a shared null block: the length mask zeroes their
    contribution); lengths: (B,) int32 valid tokens per sequence.
    Returns (B, KH, G, dh).

    The tables and lengths ride in as scalar-prefetch operands
    (``PrefetchScalarGridSpec``) so the K/V BlockSpec index map can address
    the pool per grid step — one compiled kernel serves every table, and two
    sequences whose tables alias the same pool blocks (shared prefixes) read
    the block out of HBM once per sequence with zero copies.
    """
    b, kh, g, dh = q.shape
    block_size = k_pool.shape[2]
    max_blocks = block_tables.shape[1]
    grid = (b, kh, max_blocks)

    kernel = functools.partial(_paged_decode_kernel, scale=dh ** -0.5,
                               block_size=block_size, max_blocks=max_blocks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, dh),
                         lambda b_, h_, j, tbl, lens: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_size, dh),
                         lambda b_, h_, j, tbl, lens: (tbl[b_, j], h_, 0, 0)),
            pl.BlockSpec((1, 1, block_size, dh),
                         lambda b_, h_, j, tbl, lens: (tbl[b_, j], h_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda b_, h_, j, tbl, lens: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=resolve_interpret(interpret),
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pool, v_pool)
