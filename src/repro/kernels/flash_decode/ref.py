"""Pure-jnp oracle for the flash_decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_decode_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     bias: jax.Array) -> jax.Array:
    """q: (B, KH, G, dh); caches: (B, KH, W, dh); bias: (B, W)."""
    dh = q.shape[-1]
    s = jnp.einsum("bhgd,bhwd->bhgw", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * dh ** -0.5
    s = s + bias[:, None, None, :].astype(jnp.float32)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgw,bhwd->bhgd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)
