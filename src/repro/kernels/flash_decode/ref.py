"""Pure-jnp oracles for the flash_decode kernels (contiguous and paged)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.kernel import NEG_INF


def flash_decode_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     bias: jax.Array) -> jax.Array:
    """q: (B, KH, G, dh); caches: (B, KH, W, dh); bias: (B, W)."""
    dh = q.shape[-1]
    s = jnp.einsum("bhgd,bhwd->bhgw", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * dh ** -0.5
    s = s + bias[:, None, None, :].astype(jnp.float32)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgw,bhwd->bhgd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_decode_paged_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           block_tables: jax.Array, lengths: jax.Array,
                           ) -> jax.Array:
    """Oracle for ``flash_decode_paged``: materialize each sequence's lane by
    gathering its table's blocks out of the pool, mask rows past the
    sequence length, and run the dense reference.

    q: (B, KH, G, dh); pools: (num_blocks, KH, block_size, dh);
    block_tables: (B, max_blocks); lengths: (B,).
    """
    b, kh, g, dh = q.shape
    bs = k_pool.shape[2]
    max_blocks = block_tables.shape[1]
    # (B, max_blocks, KH, bs, dh) -> (B, KH, max_blocks*bs, dh)
    k = jnp.moveaxis(k_pool[block_tables], 2, 1).reshape(b, kh, -1, dh)
    v = jnp.moveaxis(v_pool[block_tables], 2, 1).reshape(b, kh, -1, dh)
    pos = jnp.arange(max_blocks * bs)
    bias = jnp.where(pos[None, :] < lengths[:, None], 0.0, NEG_INF)
    return flash_decode_ref(q, k, v, bias.astype(jnp.float32))
