"""Jitted public wrapper for flash_decode: ring-mask construction + padding.

``decode_attention_pallas`` mirrors the signature of
``repro.models.attention.decode_attention`` (its XLA twin) so the two are
drop-in interchangeable behind the model's ``attn_impl`` switch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.kernel import flash_decode, NEG_INF
from repro.models.attention import ring_slot_positions


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention_pallas(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, pos: jax.Array, *,
                            window: Optional[int] = None, block_k: int = 512,
                            interpret: bool = True) -> jax.Array:
    """q: (B, H, dh); caches: (B, W, KH, dh); pos: scalar → (B, H, dh)."""
    b, h, dh = q.shape
    w, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh

    slot_pos = ring_slot_positions(jnp.asarray(pos) + 1, w)   # (W,)
    valid = slot_pos >= 0
    if window is not None:
        valid &= pos - slot_pos < window
    valid &= slot_pos <= pos
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    bias = jnp.broadcast_to(bias[None], (b, w))

    qg = q.reshape(b, kh, g, dh)
    kc = k_cache.transpose(0, 2, 1, 3)                        # (B, KH, W, dh)
    vc = v_cache.transpose(0, 2, 1, 3)

    bk = min(block_k, w)
    pad = (-w) % bk
    if pad:
        kc = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, 0), (0, pad), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, pad)), constant_values=NEG_INF)

    out = flash_decode(qg, kc, vc, bias, block_k=bk, interpret=interpret)
    return out.reshape(b, h, dh)
