"""Jitted public wrappers for flash_decode (contiguous ring lanes) and
flash_decode_paged (block-table-gathered pool).

``decode_attention_pallas`` mirrors the signature of
``repro.models.attention.decode_attention`` (its XLA twin) so the two are
drop-in interchangeable behind the model's ``attn_impl`` switch.
``paged_decode_attention_pallas`` is the block-table analogue over a global
``(num_blocks, KH, block_size, dh)`` KV pool.

``interpret=None`` auto-detects the backend: the compiled kernel runs on
TPU, interpret mode everywhere else (the CI container is CPU-only).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.kernel import (NEG_INF, flash_decode,
                                               flash_decode_paged)
from repro.models.attention import ring_slot_positions


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention_pallas(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, pos: jax.Array, *,
                            window: Optional[int] = None, block_k: int = 512,
                            interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, H, dh); caches: (B, W, KH, dh); pos: scalar → (B, H, dh)."""
    b, h, dh = q.shape
    w, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh

    slot_pos = ring_slot_positions(jnp.asarray(pos) + 1, w)   # (W,)
    valid = slot_pos >= 0
    if window is not None:
        valid &= pos - slot_pos < window
    valid &= slot_pos <= pos
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    bias = jnp.broadcast_to(bias[None], (b, w))

    qg = q.reshape(b, kh, g, dh)
    kc = k_cache.transpose(0, 2, 1, 3)                        # (B, KH, W, dh)
    vc = v_cache.transpose(0, 2, 1, 3)

    bk = min(block_k, w)
    pad = (-w) % bk
    if pad:
        kc = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, 0), (0, pad), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, pad)), constant_values=NEG_INF)

    out = flash_decode(qg, kc, vc, bias, block_k=bk, interpret=interpret)
    return out.reshape(b, h, dh)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q: jax.Array, k_pool: jax.Array,
                                  v_pool: jax.Array, block_tables: jax.Array,
                                  lengths: jax.Array, *,
                                  interpret: Optional[bool] = None,
                                  ) -> jax.Array:
    """q: (B, H, dh); pools: (num_blocks, KH, block_size, dh);
    block_tables: (B, max_blocks) int32; lengths: (B,) int32 → (B, H, dh).

    GQA folding only — masking lives in the kernel (rows at token positions
    ``>= lengths[b]`` contribute nothing, so table padding entries may point
    at any valid pool block)."""
    b, h, dh = q.shape
    kh = k_pool.shape[1]
    qg = q.reshape(b, kh, h // kh, dh)
    out = flash_decode_paged(qg, k_pool, v_pool, block_tables, lengths,
                             interpret=interpret)
    return out.reshape(b, h, dh)
