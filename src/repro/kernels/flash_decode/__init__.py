from repro.kernels.flash_decode.kernel import *  # noqa
