"""Pallas TPU kernels for the serving substrate's compute hot-spots.

The paper's contribution is scheduling (no kernel novelty); these kernels are
the perf-critical layers of the serving stack it plugs into, TPU-adapted per
DESIGN.md §4. Each subpackage ships kernel.py (pl.pallas_call + BlockSpec
VMEM tiling), ops.py (jitted wrapper), ref.py (pure-jnp oracle):

  flash_prefill  — causal GQA flash attention (training / prefill)
  flash_decode   — one-token decode over a (ring) KV cache, online softmax
  rwkv6_chunk    — chunked linear attention with data-dependent decay
                   (RWKV-6 "rwkv" mode + Mamba-2/SSD "ssd" mode)
"""
