"""Model zoo: multi-family transformer substrate (see transformer.py)."""
from repro.models.model import ModelBundle, batch_spec, build, decode_specs, example_batch, lm_loss

__all__ = ["ModelBundle", "batch_spec", "build", "decode_specs",
           "example_batch", "lm_loss"]
