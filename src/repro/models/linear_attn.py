"""Chunked linear attention with per-step (data-dependent) decay.

One engine serves two recurrences:

* ``mode="rwkv"`` (RWKV-6 time-mix [arXiv:2404.05892]):
      S_t = diag(w_t) S_{t-1} + k_t v_t^T
      o_t = q_t S_{t-1} + (q_t · (u ⊙ k_t)) v_t        (u = bonus param)
  with a *vector* decay w_t ∈ (0,1)^{dk} per step.

* ``mode="ssd"`` (Mamba-2 / SSD [used by the Hymba SSM heads]):
      S_t = a_t S_{t-1} + k_t v_t^T                     (scalar decay a_t)
      o_t = q_t S_t
  i.e. the current token contributes (q_t · k_t) v_t with no decay.

Both are computed in O(T·C·d) chunks: intra-chunk via a decay-weighted
attention matrix, inter-chunk via the carried state. All decay algebra runs
in f32 log-space.

NUMERICS CONTRACT: callers must clamp per-step log-decay to [-MAX_LOG_DECAY, 0]
(see ``MAX_LOG_DECAY``); with chunk_size · MAX_LOG_DECAY ≤ 80 the intra-chunk
exponentials stay inside the f32 range. The model code enforces the clamp.

The Pallas kernel ``repro.kernels.rwkv6_chunk`` implements the same chunked
algorithm with VMEM-resident (C, d) tiles; this module is its oracle.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

MAX_LOG_DECAY = 1.0  # per-step |log w| bound enforced by callers


def _chunk(x: jax.Array, c: int) -> jax.Array:
    b, h, t, d = x.shape
    return x.reshape(b, h, t // c, c, d)


def chunked_linear_attention(
    q: jax.Array,            # (B, H, T, dk)
    k: jax.Array,            # (B, H, T, dk)
    v: jax.Array,            # (B, H, T, dv)
    log_decay: jax.Array,    # (B, H, T, dk) vector, or (B, H, T, 1) scalar
    *,
    bonus: Optional[jax.Array] = None,   # (H, dk) — rwkv "u" param
    mode: str = "rwkv",
    chunk_size: int = 64,
    initial_state: Optional[jax.Array] = None,  # (B, H, dk, dv) f32
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,H,T,dv) in q.dtype, final_state (B,H,dk,dv) f32)."""
    assert mode in ("rwkv", "ssd")
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk_size, t)
    if t % c != 0:
        raise ValueError(f"T={t} not divisible by chunk_size={c}")

    qf = _chunk(q.astype(jnp.float32), c)
    kf = _chunk(k.astype(jnp.float32), c)
    vf = _chunk(v.astype(jnp.float32), c)
    lw = _chunk(jnp.broadcast_to(log_decay.astype(jnp.float32),
                                 (b, h, t, log_decay.shape[-1])), c)

    s0 = (initial_state if initial_state is not None
          else jnp.zeros((b, h, dk, dv), jnp.float32))

    # strict-lower mask (j < t) for the intra-chunk attention matrix
    tri = jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)

    def body(state, xs):
        qc, kc, vc, lwc = xs                                   # (B,H,C,·)
        inc = jnp.cumsum(lwc, axis=-2)                         # inclusive Σ_{i≤t}
        exc = inc - lwc                                        # exclusive Σ_{i<t}
        tot = inc[..., -1:, :]                                 # (B,H,1,dk)
        if mode == "rwkv":
            q_dec = qc * jnp.exp(exc)                          # decay to t-1
        else:
            q_dec = qc * jnp.exp(inc)                          # decay through t
        k_dec = kc * jnp.exp(-inc)                             # undo decay at j
        k_tail = kc * jnp.exp(tot - inc)                       # decay j → chunk end

        inter = jnp.einsum("bhcd,bhde->bhce", q_dec, state)    # vs carried state
        att = jnp.einsum("bhcd,bhjd->bhcj", q_dec, k_dec) * tri
        intra = jnp.einsum("bhcj,bhje->bhce", att, vc)
        if mode == "rwkv":
            diag_coef = jnp.sum(qc * bonus[None, :, None, :] * kc, -1, keepdims=True)
        else:
            diag_coef = jnp.sum(qc * kc, -1, keepdims=True)
        out = inter + intra + diag_coef * vc

        # decay carried state through the whole chunk, add this chunk's rank-C update
        state = (state * jnp.exp(tot).transpose(0, 1, 3, 2)
                 + jnp.einsum("bhjd,bhje->bhde", k_tail, vc))
        return state, out

    xs = tuple(x.transpose(2, 0, 1, 3, 4) for x in (qf, kf, vf, lw))
    state, outs = jax.lax.scan(body, s0, xs)
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, t, dv)
    return out.astype(q.dtype), state


def linear_attention_step(
    state: jax.Array,        # (B, H, dk, dv) f32
    q: jax.Array,            # (B, H, dk)
    k: jax.Array,            # (B, H, dk)
    v: jax.Array,            # (B, H, dv)
    log_decay: jax.Array,    # (B, H, dk) or (B, H, 1)
    *,
    bonus: Optional[jax.Array] = None,
    mode: str = "rwkv",
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrent update. Returns (out (B,H,dv), new_state)."""
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    w = jnp.exp(jnp.broadcast_to(log_decay.astype(jnp.float32), kf.shape))
    outer = jnp.einsum("bhd,bhe->bhde", kf, vf)
    if mode == "rwkv":
        out = (jnp.einsum("bhd,bhde->bhe", qf, state)
               + jnp.sum(qf * bonus[None] * kf, -1, keepdims=True) * vf)
        new_state = state * w[..., None] + outer
    else:
        new_state = state * w[..., None] + outer
        out = jnp.einsum("bhd,bhde->bhe", qf, new_state)
    return out.astype(q.dtype), new_state
