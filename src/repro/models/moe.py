"""Mixture-of-Experts FFN with GLaM-style grouped one-hot dispatch.

Tokens are reshaped into groups of ``group_size``; within each group every
expert has a fixed capacity C = ceil(group_size * top_k / E * capacity_factor)
(rounded up to a multiple of 8 for TPU lane alignment). Dispatch/combine are
einsums against a (G, T, E, C) one-hot tensor — fully static shapes, no
dynamic gather, so GSPMD can shard groups over (data, model) and experts over
model and insert the all-to-alls itself (DESIGN.md §4).

Losses: switch-style load-balance auxiliary loss and router z-loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

import os

from repro.configs.base import MoEConfig
from repro.models.common import dense_init, activate, gated
from repro.sharding.annotate import with_sharding

# §Perf iteration A1 A/B switch: "moe_group" reproduces the pre-fix conflicting
# annotation (G over (data,model) while E wants model) for baseline runs.
_GROUP_AXES = os.environ.get("REPRO_MOE_GROUP_AXES", "moe_group_dp")


def init_moe(key, d_model: int, moe: MoEConfig, activation: str, dtype) -> dict:
    ks = jax.random.split(key, 4)
    e, f = moe.num_experts, moe.expert_d_ff
    p = {
        "router": dense_init(ks[0], (d_model, e), dtype=jnp.float32),
        "w_up": dense_init(ks[1], (e, d_model, f), in_axis_size=d_model, dtype=dtype),
        "w_down": dense_init(ks[2], (e, f, d_model), in_axis_size=f, dtype=dtype),
    }
    if gated(activation):
        p["w_gate"] = dense_init(ks[3], (e, d_model, f), in_axis_size=d_model, dtype=dtype)
    return p


def _capacity(group_size: int, moe: MoEConfig) -> int:
    c = int(group_size * moe.top_k * moe.capacity_factor / moe.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8, min 8


def apply_moe(params: dict, x: jax.Array, moe: MoEConfig, activation: str,
              ) -> Tuple[jax.Array, dict]:
    """x: (B, S, d) -> (y (B,S,d), aux {load_balance_loss, router_z_loss}).

    Internally reshapes to (G, T, d) groups. B*S must be divisible by the
    effective group size (callers guarantee this; decode uses one group).
    """
    b, s, d = x.shape
    tokens = b * s
    tg = min(moe.group_size, tokens)
    assert tokens % tg == 0, f"tokens={tokens} not divisible by group={tg}"
    g = tokens // tg
    e, k = moe.num_experts, moe.top_k
    cap = _capacity(tg, moe)

    xg = x.reshape(g, tg, d)
    # G shards over the data axes ONLY: the expert dim of the dispatch einsum
    # owns the model axis, and giving G both axes forces SPMD to replicate
    # the (G,T,E,C) tensors (§Perf iteration 1 — 40x collective reduction)
    xg = with_sharding(xg, (_GROUP_AXES, None, None))

    # bf16 operands + f32 accumulation: casting xg to f32 here would make the
    # *backward* activation gradient f32 end-to-end, doubling the per-layer
    # TP all-reduce payload (§Perf iteration 3)
    logits = jnp.einsum("gtd,de->gte", xg,
                        params["router"].astype(xg.dtype),
                        preferred_element_type=jnp.float32)      # (G,T,E) f32
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # (G,T,k)
    # normalize the selected gates (DeepSeek/Mixtral convention)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # --- build dispatch + combine tensors slot by slot (k ≤ 8: python loop) --
    combine = jnp.zeros((g, tg, e, cap), jnp.float32)
    counts = jnp.zeros((g, 1, e), jnp.int32)                    # tokens routed so far
    for slot in range(k):
        sel = jax.nn.one_hot(gate_idx[..., slot], e, dtype=jnp.int32)  # (G,T,E)
        pos = jnp.cumsum(sel, axis=1) - sel + counts            # position within expert
        keep = (pos < cap) & (sel > 0)
        counts = counts + jnp.sum(sel, axis=1, keepdims=True)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap, dtype=jnp.float32)
        disp_slot = sel.astype(jnp.float32)[..., None] * pos_oh  # (G,T,E,C)
        combine = combine + disp_slot * gate_vals[..., slot][..., None, None]
    combine = with_sharding(combine, ("moe_group_dp", None, "expert", None))
    dispatch = (combine > 0).astype(x.dtype)                     # (G,T,E,C)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)              # (G,E,C,d)
    xe = with_sharding(xe, ("moe_group_dp", "expert", None, None))

    up = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    gate_proj = (jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
                 if "w_gate" in params else None)
    h = activate(up, gate_proj, activation)
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])       # (G,E,C,d)
    ye = with_sharding(ye, ("moe_group_dp", "expert", None, None))

    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)
    y = y.reshape(b, s, d)

    # --- aux losses ---------------------------------------------------------
    # load balance: E * Σ_e fraction_routed(e) * mean_prob(e)   [Switch eq.4-6]
    top1 = jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32)
    frac = jnp.mean(top1, axis=(0, 1))
    mean_p = jnp.mean(probs, axis=(0, 1))
    lb = e * jnp.sum(frac * mean_p) * moe.aux_loss_coef
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))) * moe.router_z_coef
    return y, {"load_balance_loss": lb, "router_z_loss": z}
