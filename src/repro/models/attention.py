"""Attention implementations (GQA, causal / bidirectional / sliding-window).

Two XLA paths are provided and selected by ``impl``:

* ``"naive"``  — materializes the full (S_q × S_kv) score matrix. This is the
  straightforward port and serves as the §Perf *baseline*.
* ``"chunked"``— flash-style online-softmax over KV blocks via ``lax.scan``;
  peak memory per layer drops from O(S²) to O(S·chunk). This is the
  optimized default (see EXPERIMENTS.md §Perf).

The Pallas TPU kernels in ``repro.kernels`` implement the same math with
explicit VMEM BlockSpecs; they are validated against these references in
interpret mode (CPU container — TPU is the target, not the runtime).

Shapes follow the (batch, seq, heads, head_dim) convention; GQA is handled by
folding query heads into groups of ``q_per_kv`` per KV head.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _split_gqa(q: jax.Array, num_kv: int) -> jax.Array:
    """(B, S, H, dh) -> (B, S, KH, qpk, dh)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


def _mask(pos_q, pos_kv, *, causal: bool, window: Optional[int]):
    """Validity mask (..., S_q, S_kv) from absolute positions.

    pos_q: (B, S_q) ; pos_kv: (B, S_kv). Negative kv positions are invalid
    (used for ring-buffer slots that have not been written yet).
    """
    m = pos_kv[:, None, :] >= 0
    if causal:
        m &= pos_kv[:, None, :] <= pos_q[:, :, None]
    if window is not None:
        m &= pos_q[:, :, None] - pos_kv[:, None, :] < window
    return m  # (B, S_q, S_kv)


def attention_naive(q, k, v, pos_q, pos_kv, *, causal=True,
                    window: Optional[int] = None) -> jax.Array:
    """Reference attention. q: (B,Sq,H,dh), k/v: (B,Skv,KH,dh) -> (B,Sq,H,dh).

    Operands stay in their storage dtype with f32 *accumulation*
    (``preferred_element_type``) — casting K/V to f32 would materialize an
    f32 copy of the whole KV cache every decode layer (§Perf pair B, iter 3:
    −430 GB/step HBM traffic on qwen2-vl-72b decode_32k). Softmax statistics
    remain f32.
    """
    b, sq, h, d = q.shape
    kh = k.shape[2]
    qg = _split_gqa(q, kh)                                     # (B,Sq,KH,G,dh)
    scale = d ** -0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    mask = _mask(pos_q, pos_kv, causal=causal, window=window)  # (B,Sq,Skv)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)                    # f32
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def attention_chunked(q, k, v, pos_q, pos_kv, *, causal=True,
                      window: Optional[int] = None,
                      kv_chunk: int = 1024) -> jax.Array:
    """Online-softmax attention, scanning KV in chunks of ``kv_chunk``.

    Peak live memory: (B,KH,G,Sq,kv_chunk) scores instead of (...,S_kv).
    Numerics: running max/sum in f32, identical to flash attention.
    """
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    if skv % kv_chunk != 0:
        # Fall back for ragged sizes (smoke tests); correctness first.
        return attention_naive(q, k, v, pos_q, pos_kv, causal=causal, window=window)
    g = h // kh
    qg = _split_gqa(q, kh).transpose(0, 2, 3, 1, 4)            # (B,KH,G,Sq,dh)
    scale = jnp.float32(d ** -0.5)

    n_chunks = skv // kv_chunk
    k_c = k.reshape(b, n_chunks, kv_chunk, kh, d)
    v_c = v.reshape(b, n_chunks, kv_chunk, kh, d)
    pos_c = pos_kv.reshape(b, n_chunks, kv_chunk)

    def body(carry, xs):
        m_prev, l_prev, acc = carry                            # (B,KH,G,Sq,[1|dh])
        kc, vc, pc = xs                                        # (B,C,KH,dh), (B,C)
        s = jnp.einsum("bkgqd,bckd->bkgqc", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = _mask(pos_q, pc, causal=causal, window=window)  # (B,Sq,C)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum("bkgqc,bckd->bkgqd", p.astype(vc.dtype),
                                      vc, preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kh, g, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, kh, g, sq, d), jnp.float32)
    # scan over the chunk axis (moved to leading position)
    xs = (k_c.transpose(1, 0, 2, 3, 4), v_c.transpose(1, 0, 2, 3, 4),
          pos_c.transpose(1, 0, 2))
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


def attention(q, k, v, pos_q, pos_kv, *, causal=True, window=None,
              impl: str = "chunked", kv_chunk: int = 1024) -> jax.Array:
    if impl == "naive" or k.shape[1] <= kv_chunk:
        return attention_naive(q, k, v, pos_q, pos_kv, causal=causal, window=window)
    return attention_chunked(q, k, v, pos_q, pos_kv, causal=causal,
                             window=window, kv_chunk=kv_chunk)


# ---------------------------------------------------------------------------
# Decode (single query token against a [ring-buffer] KV cache)
# ---------------------------------------------------------------------------
def ring_slot_positions(pos: jax.Array, cache_len: int) -> jax.Array:
    """Absolute position stored in each ring-buffer slot, -1 if unwritten.

    ``pos`` is the position of the token being decoded *now* (scalar int32);
    slots hold positions < pos. Slot j holds the largest p < pos with
    p % cache_len == j.
    """
    j = jnp.arange(cache_len, dtype=jnp.int32)
    p = pos - 1 - jnp.mod(pos - 1 - j, cache_len)
    return jnp.where(p >= 0, p, -1)


def decode_attention(q, k_cache, v_cache, pos, *, window: Optional[int] = None,
                     ) -> jax.Array:
    """One-token attention. q: (B,H,dh); caches: (B,W,KH,dh); pos: scalar.

    The caches are ring buffers when ``window`` is set (W == window), plain
    append buffers otherwise (W == max_len). The current token's K/V must
    already be written to the cache by the caller.

    Always the single-einsum ("naive") form: under a sequence-sharded cache,
    GSPMD partitions the W contraction with a small partial-softmax
    all-reduce, whereas a kv-chunk scan dynamic-slices across the sharded dim
    and triggers involuntary full rematerialization (§Perf pair B, iter 2).
    On-chip blocking over W is the Pallas flash_decode kernel's job.
    """
    b, h, d = q.shape
    w, kh = k_cache.shape[1], k_cache.shape[2]
    slot_pos = ring_slot_positions(pos + 1, w)                 # includes current
    pos_kv = jnp.broadcast_to(slot_pos[None], (b, w))
    pos_q = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None], (b, 1))
    out = attention_naive(q[:, None], k_cache, v_cache, pos_q, pos_kv,
                          causal=True, window=window)
    return out[:, 0]                                           # (B,H,dh)


def update_cache(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write one token's K or V into the (ring) cache.

    cache: (B, W, KH, dh); new: (B, KH, dh); pos: scalar absolute position.
    """
    w = cache.shape[1]
    slot = jnp.mod(jnp.asarray(pos, jnp.int32), w)
    return jax.lax.dynamic_update_slice(cache, new[:, None], (0, slot, 0, 0))


def prefill_cache(k: jax.Array, v: jax.Array, cache_len: int):
    """Build decode caches from prefill K/V. k/v: (B,S,KH,dh) -> (B,W,KH,dh).

    For windowed attention (cache_len < S) keeps the last ``cache_len``
    positions arranged at their ring slots so decode can continue seamlessly.
    """
    b, s, kh, d = k.shape
    if cache_len >= s:
        pad = [(0, 0), (0, cache_len - s), (0, 0), (0, 0)]
        return jnp.pad(k, pad), jnp.pad(v, pad)
    # last cache_len tokens, placed at slot = position % cache_len
    tail_pos = jnp.arange(s - cache_len, s)
    slots = jnp.mod(tail_pos, cache_len)
    k_tail, v_tail = k[:, -cache_len:], v[:, -cache_len:]
    kc = jnp.zeros((b, cache_len, kh, d), k.dtype).at[:, slots].set(k_tail)
    vc = jnp.zeros((b, cache_len, kh, d), v.dtype).at[:, slots].set(v_tail)
    return kc, vc
