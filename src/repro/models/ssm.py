"""Mamba-2 / SSD selective-state-space block (the Hymba SSM heads).

    h_t = exp(-exp(A_log)·Δ_t) · h_{t-1} + Δ_t B_t x_t
    y_t = C_t h_t ,   y = y ⊙ silu(z) @ W_out

Computed with the shared chunked linear-attention engine (scalar per-head
decay, ``mode="ssd"``). B/C are shared across heads (MQA-style, as in
Mamba-2). Depthwise causal conv with a (conv_width-1) tail carried as decode
state. Log-decay clamped to the engine's numerics contract.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.common import dense_init
from repro.models.linear_attn import (MAX_LOG_DECAY, chunked_linear_attention,
                                      linear_attention_step)
from repro.sharding.annotate import with_sharding


def _dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.state_size


def init_ssm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    d_inner, nh, n = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, 2 * d_inner), dtype=dtype),     # x | z
        "conv": (jax.random.normal(ks[1], (s.conv_width, d_inner), jnp.float32)
                 * (1.0 / s.conv_width)).astype(dtype),
        "w_bc": dense_init(ks[2], (d_inner, 2 * n), dtype=dtype),     # B | C
        "w_dt": dense_init(ks[3], (d_inner, nh), dtype=dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "a_log": jnp.zeros((nh,), jnp.float32),                        # A = -exp(a_log)
        "w_out": dense_init(ks[4], (d_inner, d), in_axis_size=d_inner, dtype=dtype),
    }


def _conv(x: jax.Array, w: jax.Array, tail: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Causal depthwise conv. x: (B,T,Di), w: (K,Di), tail: (B,K-1,Di)."""
    k = w.shape[0]
    xp = jnp.concatenate([tail, x], axis=1)                   # (B, T+K-1, Di)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out), xp[:, -(k - 1):]


def _gates(p: dict, xc: jax.Array, nh: int, n: int):
    """Common post-conv projections. xc: (B,T,Di) → (q,k per head, dt, log_decay)."""
    bc = xc @ p["w_bc"]
    b_in, c_out = jnp.split(bc, 2, axis=-1)                   # (B,T,N) each
    dt = jax.nn.softplus((xc @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"])                       # (B,T,nh)
    log_decay = jnp.clip(-jnp.exp(p["a_log"]) * dt, -MAX_LOG_DECAY, -1e-6)
    return b_in, c_out, dt, log_decay


def apply_ssm(p: dict, x: jax.Array, cfg: ModelConfig, *,
              conv_tail=None, state=None):
    """Sequence mode. x: (B,T,d) → (y (B,T,d), conv_tail, state)."""
    b, t, _ = x.shape
    s = cfg.ssm
    d_inner, nh, n = _dims(cfg)
    xz = x @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)
    if conv_tail is None:
        conv_tail = jnp.zeros((b, s.conv_width - 1, d_inner), x.dtype)
    xc, conv_tail = _conv(xi, p["conv"], conv_tail)
    xc = with_sharding(xc, ("batch", None, "d_inner"))

    b_in, c_out, dt, log_decay = _gates(p, xc, nh, n)
    # fold Δ into v; broadcast shared B/C over heads
    v = (xc.reshape(b, t, nh, s.head_dim)
         * dt[..., None].astype(x.dtype)).transpose(0, 2, 1, 3)   # (B,nh,T,dh)
    q = jnp.broadcast_to(c_out[:, None], (b, nh, t, n))
    kk = jnp.broadcast_to(b_in[:, None], (b, nh, t, n))
    lw = log_decay.transpose(0, 2, 1)[..., None]               # (B,nh,T,1)

    y, state = chunked_linear_attention(q, kk, v, lw, mode="ssd",
                                        chunk_size=s.chunk_size,
                                        initial_state=state)
    y = y.transpose(0, 2, 1, 3).reshape(b, t, d_inner)
    y = (y * jax.nn.silu(z)) @ p["w_out"]
    return y, conv_tail, state


def ssm_step(p: dict, x: jax.Array, cfg: ModelConfig, conv_tail, state):
    """One-token recurrent mode. x: (B,d) → (y (B,d), conv_tail, state)."""
    b, _ = x.shape
    s = cfg.ssm
    d_inner, nh, n = _dims(cfg)
    xz = x @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([conv_tail, xi[:, None]], axis=1)  # (B,K,Di)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, p["conv"]))
    conv_tail = window[:, 1:]

    b_in, c_out, dt, log_decay = _gates(p, xc[:, None], nh, n)
    v = (xc.reshape(b, nh, s.head_dim) * dt[:, 0, :, None].astype(x.dtype))
    q = jnp.broadcast_to(c_out[:, 0, None], (b, nh, n))
    kk = jnp.broadcast_to(b_in[:, 0, None], (b, nh, n))
    y, state = linear_attention_step(state, q, kk, v,
                                     log_decay[:, 0, :, None], mode="ssd")
    y = y.reshape(b, d_inner)
    y = (y * jax.nn.silu(z)) @ p["w_out"]
    return y, conv_tail, state
