"""RWKV-6 "Finch" blocks [arXiv:2404.05892]: time-mix + channel-mix.

Faithful structure: token-shift lerps, data-dependent per-channel decay via a
low-rank adapter, bonus-``u`` current-token term, per-head group norm, and a
squared-ReLU channel-mix. One documented deviation (DESIGN.md §8): the decay
is parameterized as ``log w = -MAX_LOG_DECAY * sigmoid(w0 + lora(x))`` instead
of ``-exp(w0 + lora(x))`` so the per-step log-decay is bounded in (-1, 0) —
the numerics contract of the chunked kernel (see models/linear_attn.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, group_norm_heads
from repro.models.linear_attn import (MAX_LOG_DECAY, chunked_linear_attention,
                                      linear_attention_step)
from repro.sharding.annotate import with_sharding

DECAY_LORA = 64


def init_time_mix(key, cfg: ModelConfig, dtype) -> dict:
    d, h, dh = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 10)
    return {
        "mix": jnp.full((5, d), 0.5, dtype),            # r,k,v,w,g shift lerps
        "w_r": dense_init(ks[0], (d, h * dh), dtype=dtype),
        "w_k": dense_init(ks[1], (d, h * dh), dtype=dtype),
        "w_v": dense_init(ks[2], (d, h * dh), dtype=dtype),
        "w_g": dense_init(ks[3], (d, h * dh), dtype=dtype),
        "w_o": dense_init(ks[4], (h * dh, d), dtype=dtype),
        "decay_base": jnp.zeros((h, dh), jnp.float32),
        "decay_a": dense_init(ks[5], (d, DECAY_LORA), dtype=jnp.float32),
        "decay_b": (dense_init(ks[6], (DECAY_LORA, h * dh), dtype=jnp.float32) * 0.1),
        "bonus": jnp.zeros((h, dh), jnp.float32),
        "gn_scale": jnp.ones((h, dh), jnp.float32),
    }


def init_channel_mix(key, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mix": jnp.full((2, d), 0.5, dtype),            # k,r shift lerps
        "w_k": dense_init(ks[0], (d, f), dtype=dtype),
        "w_v": dense_init(ks[1], (f, d), in_axis_size=f, dtype=dtype),
        "w_r": dense_init(ks[2], (d, d), dtype=dtype),
    }


def _shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """Token shift: y_t = x_{t-1}; y_0 = prev. x: (B,T,d), prev: (B,d)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _log_decay(p: dict, xw: jax.Array) -> jax.Array:
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["decay_a"]) @ p["decay_b"]
    base = p["decay_base"].reshape(-1)
    return -MAX_LOG_DECAY * jax.nn.sigmoid(base + lora)     # (..., H*dh) in (-1,0)


def time_mix(p: dict, x: jax.Array, prev: jax.Array, cfg: ModelConfig,
             state=None, chunk_size: int = 64):
    """Sequence-mode time-mix. x: (B,T,d) -> (out, last_x (B,d), state)."""
    b, t, d = x.shape
    h, dh = cfg.num_heads, cfg.head_dim
    xs = _shift(x, prev)
    mix = p["mix"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + mix[i] * (xs - x) for i in range(5))
    r = (xr @ p["w_r"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    k = (xk @ p["w_k"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    v = (xv @ p["w_v"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    g = xg @ p["w_g"]
    lw = _log_decay(p, xw).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    r = with_sharding(r, ("batch", "heads", None, None))
    out, state = chunked_linear_attention(
        r, k, v, lw, bonus=p["bonus"], mode="rwkv",
        chunk_size=chunk_size, initial_state=state)
    out = out.transpose(0, 2, 1, 3)                          # (B,T,H,dh)
    out = group_norm_heads(out, p["gn_scale"]).reshape(b, t, h * dh)
    out = (out * jax.nn.silu(g)) @ p["w_o"]
    return out, x[:, -1], state


def time_mix_step(p: dict, x: jax.Array, prev: jax.Array, state: jax.Array,
                  cfg: ModelConfig):
    """One-token time-mix. x: (B,d) -> (out (B,d), new_prev, new_state)."""
    b, d = x.shape
    h, dh = cfg.num_heads, cfg.head_dim
    mix = p["mix"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + mix[i] * (prev - x) for i in range(5))
    r = (xr @ p["w_r"]).reshape(b, h, dh)
    k = (xk @ p["w_k"]).reshape(b, h, dh)
    v = (xv @ p["w_v"]).reshape(b, h, dh)
    g = xg @ p["w_g"]
    lw = _log_decay(p, xw).reshape(b, h, dh)
    out, state = linear_attention_step(state, r, k, v, lw,
                                       bonus=p["bonus"], mode="rwkv")
    out = group_norm_heads(out, p["gn_scale"]).reshape(b, h * dh)
    out = (out * jax.nn.silu(g)) @ p["w_o"]
    return out, x, state


def channel_mix(p: dict, x: jax.Array, prev: jax.Array):
    """Sequence-mode channel-mix (squared-ReLU gated MLP with token shift)."""
    xs = _shift(x, prev)
    mix = p["mix"].astype(x.dtype)
    xk = x + mix[0] * (xs - x)
    xr = x + mix[1] * (xs - x)
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    out = jax.nn.sigmoid(xr @ p["w_r"]) * (kk @ p["w_v"])
    return out, x[:, -1]


def channel_mix_step(p: dict, x: jax.Array, prev: jax.Array):
    mix = p["mix"].astype(x.dtype)
    xk = x + mix[0] * (prev - x)
    xr = x + mix[1] * (prev - x)
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    out = jax.nn.sigmoid(xr @ p["w_r"]) * (kk @ p["w_v"])
    return out, x
