"""Public model API: build, loss, prefill/decode entry points, input specs.

``input_specs`` is the dry-run contract: weak-type-correct
``jax.ShapeDtypeStruct`` stand-ins for every model input (no allocation),
including the modality-frontend STUBS — VLM patch embeddings and audio frame
embeddings arrive pre-computed, per the assignment carve-out.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, InputShape, AUDIO, VLM,
                                config_for_shape)
from repro.models import transformer as tfm

PyTree = Any


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array) -> jax.Array:
    """Masked token-mean CE. logits: (B,S,V); targets/mask: (B,S).

    Vocab-sharding-friendly (§Perf iteration 2): the gold logit is selected
    with a one-hot einsum instead of ``take_along_axis`` — a gather over the
    sharded vocab dim forces GSPMD to all-gather the full f32 (B,S,V) logits
    (tens of GB/device for 256k vocabs); the einsum keeps V sharded and
    reduces to (B,S) with a small all-reduce.
    """
    from repro.sharding.annotate import with_sharding
    lf = logits.astype(jnp.float32)
    lf = with_sharding(lf, ("batch", None, "vocab"))
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    onehot = with_sharding(onehot, ("batch", None, "vocab"))
    gold = jnp.einsum("bsv,bsv->bs", lf, onehot)
    nll = (lse - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_loss(params: PyTree, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            attn_impl: str = "chunked", remat: str = "full",
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, _, aux = tfm.forward_seq(
        params, cfg, batch["tokens"],
        vision_embeds=batch.get("vision_embeds"),
        mrope_positions=batch.get("mrope_positions"),
        frames=batch.get("frames"),
        attn_impl=attn_impl, remat=remat)
    ce = cross_entropy(logits, batch["targets"], batch["loss_mask"])
    loss = ce + aux["load_balance_loss"] + aux["router_z_loss"]
    metrics = {"ce": ce, **aux}
    return loss, metrics


# ---------------------------------------------------------------------------
# Bundle
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable[..., PyTree]
    loss_fn: Callable[..., Tuple[jax.Array, Dict]]
    prefill: Callable[..., Tuple[jax.Array, PyTree]]
    decode_step: Callable[..., Tuple[jax.Array, PyTree]]
    init_cache: Callable[..., PyTree]


def build(cfg: ModelConfig, *, attn_impl: str = "chunked",
          remat: str = "full") -> ModelBundle:
    def init(key, param_dtype=None):
        return tfm.init_params(key, cfg, param_dtype)

    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch, attn_impl=attn_impl, remat=remat)

    def prefill(params, tokens, cache_len, **extras):
        logits, cache, _ = tfm.forward_seq(
            params, cfg, tokens, build_cache=True, cache_len=cache_len,
            attn_impl=attn_impl, remat="none", **extras)
        return logits, cache

    def decode_step(params, cache, token):
        return tfm.decode_step(params, cfg, cache, token)

    def init_cache(batch, max_len, pos=0, dtype=None):
        return tfm.init_cache(cfg, batch, max_len, pos=pos, dtype=dtype)

    return ModelBundle(cfg, init, loss_fn, prefill, decode_step, init_cache)


# ---------------------------------------------------------------------------
# Input specs & example batches
# ---------------------------------------------------------------------------
def _act_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def batch_spec(cfg: ModelConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training/prefill batch spec for (arch × input shape)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    spec: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == VLM:
        p = cfg.vision_prefix_len
        s_text = s - p
        spec["tokens"] = jax.ShapeDtypeStruct((b, s_text), i32)
        spec["vision_embeds"] = jax.ShapeDtypeStruct((b, p, cfg.d_model),
                                                     _act_dtype(cfg))
        spec["mrope_positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
    elif cfg.family == AUDIO:
        spec["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        spec["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq_len,
                                               cfg.d_model), _act_dtype(cfg))
    else:
        spec["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    spec["targets"] = jax.ShapeDtypeStruct((b, s), i32)
    spec["loss_mask"] = jax.ShapeDtypeStruct((b, s), jnp.float32)
    return spec


def decode_specs(cfg: ModelConfig, shape: InputShape):
    """(cache_spec, token_spec) for decode-shape dry-runs."""
    cfg = config_for_shape(cfg, shape)
    b = shape.global_batch
    cache = jax.eval_shape(
        lambda: tfm.init_cache(cfg, b, shape.seq_len))
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return cache, token


def example_batch(cfg: ModelConfig, batch: int, seq: int, key) -> Dict[str, jax.Array]:
    """A real (small) random batch for smoke tests."""
    ks = jax.random.split(key, 3)
    out: Dict[str, jax.Array] = {}
    if cfg.family == VLM:
        p = cfg.vision_prefix_len
        s_text = seq - p
        out["tokens"] = jax.random.randint(ks[0], (batch, s_text), 0, cfg.vocab_size)
        out["vision_embeds"] = jax.random.normal(
            ks[1], (batch, p, cfg.d_model), _act_dtype(cfg)) * 0.02
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None, None],
                               (3, batch, seq))
        out["mrope_positions"] = pos
        mask = jnp.concatenate([jnp.zeros((batch, p), jnp.float32),
                                jnp.ones((batch, s_text), jnp.float32)], 1)
    elif cfg.family == AUDIO:
        out["tokens"] = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)
        out["frames"] = jax.random.normal(
            ks[1], (batch, cfg.encoder_seq_len, cfg.d_model),
            _act_dtype(cfg)) * 0.02
        mask = jnp.ones((batch, seq), jnp.float32)
    else:
        out["tokens"] = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)
        mask = jnp.ones((batch, seq), jnp.float32)
    out["targets"] = jax.random.randint(ks[2], mask.shape, 0, cfg.vocab_size)
    out["loss_mask"] = mask
    return out
