"""Generic multi-family transformer: dense / MoE / VLM / hybrid / SSM / enc-dec.

One parameter layout and three execution modes per architecture family:

* ``forward_seq``   — full-sequence forward (training and prefill; prefill
  additionally materializes the decode cache).
* ``decode_step``   — one-token step against the cache/state pytree.
* ``encode``        — whisper-style bidirectional encoder over stub frames.

Layers are *stacked*: every leaf in ``params["layers"]`` has a leading
``num_layers`` axis and the layer loop is a single ``jax.lax.scan`` — this
keeps HLO size independent of depth (80-layer configs lower in seconds) and
gives remat a natural grain (one scan body).

All activations are tagged with logical sharding axes (see
repro/sharding/annotate.py); on CPU smoke tests the tags are no-ops.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, DENSE, MOE, SSM, HYBRID, VLM,
                                AUDIO)
from repro.models import rwkv6 as rwkv
from repro.models import ssm as ssd
from repro.models.attention import (attention, decode_attention, prefill_cache,
                                    update_cache)
from repro.models.common import (activate, apply_norm, apply_mrope, apply_rope,
                                 dense_init, embed_init, gated, init_norm,
                                 positions_for)
from repro.models.moe import apply_moe, init_moe
from repro.sharding.annotate import with_sharding

PyTree = Any


# ===========================================================================
# Parameter initialization
# ===========================================================================
def _init_attn(key, cfg: ModelConfig, dtype) -> dict:
    d, h, kh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * dh), dtype=dtype),
        "wk": dense_init(ks[1], (d, kh * dh), dtype=dtype),
        "wv": dense_init(ks[2], (d, kh * dh), dtype=dtype),
        "wo": dense_init(ks[3], (h * dh, d), in_axis_size=h * dh, dtype=dtype),
    }
    if cfg.use_bias:
        p.update(bq=jnp.zeros((h * dh,), dtype), bk=jnp.zeros((kh * dh,), dtype),
                 bv=jnp.zeros((kh * dh,), dtype), bo=jnp.zeros((d,), dtype))
    return p


def _init_mlp(key, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d, f), dtype=dtype),
        "w_down": dense_init(ks[1], (f, d), in_axis_size=f, dtype=dtype),
    }
    if gated(cfg.activation):
        p["w_gate"] = dense_init(ks[2], (d, f), dtype=dtype)
    if cfg.use_bias:
        p.update(b_up=jnp.zeros((f,), dtype), b_down=jnp.zeros((d,), dtype))
    return p


def _init_layer(key, cfg: ModelConfig, dtype, *, encoder: bool = False) -> dict:
    ks = jax.random.split(key, 8)
    p: dict = {"ln1": init_norm(cfg, dtype), "ln2": init_norm(cfg, dtype)}
    if cfg.family == SSM:
        p["tm"] = rwkv.init_time_mix(ks[0], cfg, dtype)
        p["cm"] = rwkv.init_channel_mix(ks[1], cfg, dtype)
        return p
    p["attn"] = _init_attn(ks[0], cfg, dtype)
    if cfg.family == HYBRID:
        p["ssm"] = ssd.init_ssm(ks[1], cfg, dtype)
    if not encoder and cfg.is_encdec:
        p["cross"] = _init_attn(ks[2], cfg, dtype)
        p["ln_cross"] = init_norm(cfg, dtype)
    if cfg.moe is not None and not encoder:
        p["moe"] = init_moe(ks[3], cfg.d_model, cfg.moe, cfg.activation, dtype)
        if cfg.d_ff:  # shared dense path (DeepSeek-style shared expert)
            p["mlp"] = _init_mlp(ks[4], cfg, dtype)
    elif cfg.d_ff:
        p["mlp"] = _init_mlp(ks[4], cfg, dtype)
    return p


def init_params(key, cfg: ModelConfig, param_dtype=None) -> PyTree:
    dtype = jnp.dtype(param_dtype or cfg.dtype)
    k_embed, k_layers, k_head, k_enc, k_pos = jax.random.split(key, 5)
    params: Dict[str, Any] = {
        "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": init_norm(cfg, dtype),
    }
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    params["layers"] = jax.vmap(
        lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                       dtype=dtype)
    if cfg.pos_emb == "learned":
        params["pos_embed"] = embed_init(k_pos, (8192, cfg.d_model), dtype)
    if cfg.is_encdec:
        enc_keys = jax.random.split(k_enc, cfg.encoder_layers + 2)
        params["encoder"] = {
            "layers": jax.vmap(
                lambda k: _init_layer(k, cfg, dtype, encoder=True))(
                    enc_keys[:cfg.encoder_layers]),
            "pos_embed": embed_init(enc_keys[-2],
                                    (cfg.encoder_seq_len, cfg.d_model), dtype),
            "final_norm": init_norm(cfg, dtype),
        }
    return params


# ===========================================================================
# Attention block (seq + step)
# ===========================================================================
def _qkv(p, x, cfg: ModelConfig):
    b = x.shape[0]
    s = x.shape[1] if x.ndim == 3 else 1
    h, kh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"] + (p["bq"] if "bq" in p else 0)
    k = x @ p["wk"] + (p["bk"] if "bk" in p else 0)
    v = x @ p["wv"] + (p["bv"] if "bv" in p else 0)
    return (q.reshape(b, s, h, dh), k.reshape(b, s, kh, dh),
            v.reshape(b, s, kh, dh))


def _rope_qk(q, k, cfg: ModelConfig, positions, mrope_pos):
    if cfg.pos_emb == "mrope":
        return (apply_mrope(q, mrope_pos, cfg.rope_theta),
                apply_mrope(k, mrope_pos, cfg.rope_theta))
    if cfg.pos_emb == "rope":
        return (apply_rope(q, positions, cfg.rope_theta),
                apply_rope(k, positions, cfg.rope_theta))
    return q, k


def attn_seq(p, x, cfg: ModelConfig, *, positions, mrope_pos=None,
             causal=True, attn_impl="chunked", kv_chunk=1024,
             kv_override=None):
    """Full-sequence attention. Returns (out, (k, v)) for cache building."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    if kv_override is not None:          # cross-attention: K/V from encoder
        k, v = kv_override
        pos_kv = positions_for(cfg, b, 0, k.shape[1])
    else:
        q, k = _rope_qk(q, k, cfg, positions, mrope_pos)
        pos_kv = positions
    q = with_sharding(q, ("batch", None, "heads", None))
    k = with_sharding(k, ("batch", None, "kv_heads", None))
    out = attention(q, k, v, positions, pos_kv, causal=causal,
                    window=cfg.sliding_window if causal else None,
                    impl=attn_impl, kv_chunk=kv_chunk)
    out = out.reshape(b, s, -1) @ p["wo"] + (p["bo"] if "bo" in p else 0)
    return out, (k, v)


def attn_step(p, x, cfg: ModelConfig, *, cache_k, cache_v, pos,
              mrope_pos=None, cross=False):
    """One-token attention. x: (B,1,d); caches: (B,W,KH,dh); pos scalar."""
    b = x.shape[0]
    q, k, v = _qkv(p, x, cfg)
    if cross:
        # cross-attention: cache holds the (fixed) encoder K/V; no update
        pos_kv = jnp.broadcast_to(
            jnp.arange(cache_k.shape[1], dtype=jnp.int32)[None],
            (b, cache_k.shape[1]))
        pos_q = jnp.full((b, 1), cache_k.shape[1], jnp.int32)  # attend to all
        out = attention(q, cache_k, cache_v, pos_q, pos_kv, causal=False)
        out = out.reshape(b, 1, -1) @ p["wo"] + (p["bo"] if "bo" in p else 0)
        return out, cache_k, cache_v
    positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None], (b, 1))
    mp = (jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (3, b, 1))
          if cfg.pos_emb == "mrope" else None)
    q, k = _rope_qk(q, k, cfg, positions, mp)
    cache_k = update_cache(cache_k, k[:, 0], pos)
    cache_v = update_cache(cache_v, v[:, 0], pos)
    out = decode_attention(q[:, 0], cache_k, cache_v, pos,
                           window=cfg.sliding_window)
    out = out.reshape(b, 1, -1) @ p["wo"] + (p["bo"] if "bo" in p else 0)
    return out, cache_k, cache_v


def _mlp(p, x, cfg: ModelConfig):
    up = x @ p["w_up"] + (p["b_up"] if "b_up" in p else 0)
    gate = x @ p["w_gate"] if "w_gate" in p else None
    h = activate(up, gate, cfg.activation)
    h = with_sharding(h, ("batch", None, "ff"))
    return h @ p["w_down"] + (p["b_down"] if "b_down" in p else 0)


def _ffn(lp, x, cfg: ModelConfig):
    """Dense MLP, MoE, or both (shared-expert). Returns (y, aux_losses)."""
    aux = {"load_balance_loss": 0.0, "router_z_loss": 0.0}
    y = 0.0
    if "moe" in lp:
        y_moe, aux = apply_moe(lp["moe"], x, cfg.moe, cfg.activation)
        y = y + y_moe
    if "mlp" in lp:
        y = y + _mlp(lp["mlp"], x, cfg)
    return y, aux


# ===========================================================================
# Layer bodies (per family) — sequence mode
# ===========================================================================
def layer_seq(lp, x, cfg: ModelConfig, *, positions, mrope_pos, enc_out,
              build_cache, cache_len, attn_impl, kv_chunk, chunk_size):
    """One decoder layer, full-sequence. Returns (x, cache_slices, aux)."""
    cache: Dict[str, jax.Array] = {}
    xn = apply_norm(lp["ln1"], x, cfg.norm)
    if cfg.family == SSM:
        b = x.shape[0]
        prev = jnp.zeros((b, cfg.d_model), x.dtype)
        out, last_tm, state = rwkv.time_mix(lp["tm"], xn, prev, cfg,
                                            chunk_size=chunk_size)
        x = x + out
        xn2 = apply_norm(lp["ln2"], x, cfg.norm)
        out2, last_cm = rwkv.channel_mix(lp["cm"], xn2, prev)
        x = x + out2
        if build_cache:
            cache = {"state": state, "tm_prev": last_tm, "cm_prev": last_cm}
        return x, cache, {}

    attn_out, (k, v) = attn_seq(lp["attn"], xn, cfg, positions=positions,
                                mrope_pos=mrope_pos, attn_impl=attn_impl,
                                kv_chunk=kv_chunk)
    if cfg.family == HYBRID:
        ssm_out, conv_tail, state = ssd.apply_ssm(lp["ssm"], xn, cfg)
        attn_out = 0.5 * (attn_out + ssm_out)
        if build_cache:
            cache.update(conv_tail=conv_tail, ssm_state=state)
    x = x + attn_out
    if build_cache and not cfg.attention_free:
        ck, cv = prefill_cache(k, v, cache_len)
        cache.update(k=ck, v=cv)

    if enc_out is not None:                      # whisper cross-attention
        xc = apply_norm(lp["ln_cross"], x, cfg.norm)
        _, ek, ev = _qkv(lp["cross"], enc_out, cfg)  # K/V from encoder
        # queries from decoder: reuse attn_seq with kv_override
        cross_out, _ = attn_seq(lp["cross"], xc, cfg, positions=positions,
                                kv_override=(ek, ev), causal=False,
                                attn_impl=attn_impl, kv_chunk=kv_chunk)
        x = x + cross_out
        if build_cache:
            cache.update(cross_k=ek, cross_v=ev)

    xn2 = apply_norm(lp["ln2"], x, cfg.norm)
    ffn_out, aux = _ffn(lp, xn2, cfg)
    x = x + ffn_out
    return x, cache, aux


def layer_step(lp, x, cfg: ModelConfig, cache_l: Dict[str, jax.Array], pos):
    """One decoder layer, one token. x: (B,1,d)."""
    new_cache = dict(cache_l)
    xn = apply_norm(lp["ln1"], x, cfg.norm)
    if cfg.family == SSM:
        out, last_tm, state = rwkv.time_mix_step(
            lp["tm"], xn[:, 0], cache_l["tm_prev"], cache_l["state"], cfg)
        x = x + out[:, None]
        xn2 = apply_norm(lp["ln2"], x, cfg.norm)
        out2, last_cm = rwkv.channel_mix_step(lp["cm"], xn2[:, 0],
                                              cache_l["cm_prev"])
        x = x + out2[:, None]
        new_cache.update(state=state, tm_prev=last_tm, cm_prev=last_cm)
        return x, new_cache, {}

    attn_out, ck, cv = attn_step(lp["attn"], xn, cfg, cache_k=cache_l["k"],
                                 cache_v=cache_l["v"], pos=pos)
    new_cache.update(k=ck, v=cv)
    if cfg.family == HYBRID:
        ssm_out, conv_tail, state = ssd.ssm_step(
            lp["ssm"], xn[:, 0], cfg, cache_l["conv_tail"], cache_l["ssm_state"])
        attn_out = 0.5 * (attn_out + ssm_out[:, None])
        new_cache.update(conv_tail=conv_tail, ssm_state=state)
    x = x + attn_out

    if "cross_k" in cache_l:
        xc = apply_norm(lp["ln_cross"], x, cfg.norm)
        cross_out, _, _ = attn_step(lp["cross"], xc, cfg,
                                    cache_k=cache_l["cross_k"],
                                    cache_v=cache_l["cross_v"], pos=pos,
                                    cross=True)
        x = x + cross_out

    xn2 = apply_norm(lp["ln2"], x, cfg.norm)
    ffn_out, aux = _ffn(lp, xn2, cfg)
    x = x + ffn_out
    return x, new_cache, aux


# ===========================================================================
# Whisper encoder
# ===========================================================================
def encode(params, cfg: ModelConfig, frames: jax.Array,
           attn_impl="chunked") -> jax.Array:
    """Bidirectional encoder over stub frame embeddings (B, F, d)."""
    enc = params["encoder"]
    b, f, _ = frames.shape
    x = frames + enc["pos_embed"][None, :f].astype(frames.dtype)
    positions = positions_for(cfg, b, 0, f)

    def body(x, lp):
        xn = apply_norm(lp["ln1"], x, cfg.norm)
        out, _ = attn_seq(lp["attn"], xn, cfg, positions=positions,
                          causal=False, attn_impl=attn_impl)
        x = x + out
        xn2 = apply_norm(lp["ln2"], x, cfg.norm)
        x = x + _mlp(lp["mlp"], xn2, cfg)
        return x, None

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return apply_norm(enc["final_norm"], x, cfg.norm)


# ===========================================================================
# Top level: embed → layers (scan) → norm → logits
# ===========================================================================
def _embed(params, cfg: ModelConfig, tokens, *, start_pos=0,
           vision_embeds=None):
    x = params["embed"][tokens]                 # (B,S,d) gather
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    if cfg.pos_emb == "learned":
        s = x.shape[1]
        table = params["pos_embed"]
        # modular wrap: the assigned stress shapes (32k/500k decode) exceed
        # any learned-position model's table; wrapping keeps the program
        # well-defined (DESIGN.md §5 — whisper runs decode_32k as a stress
        # config, not a semantic claim)
        ids = jnp.mod(start_pos + jnp.arange(s, dtype=jnp.int32),
                      table.shape[0])
        x = x + table[ids][None].astype(x.dtype)
    return with_sharding(x, ("batch", None, None))


def _unembed(params, cfg: ModelConfig, x):
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    return with_sharding(logits, ("batch", None, "vocab"))


def forward_seq(params, cfg: ModelConfig, tokens, *,
                vision_embeds=None, mrope_positions=None, frames=None,
                build_cache=False, cache_len=0,
                attn_impl="chunked", kv_chunk=1024, chunk_size=64,
                remat: str = "full") -> Tuple[jax.Array, Optional[PyTree], dict]:
    """Full-sequence forward.

    Returns (logits (B,S,V), cache-or-None, aux_losses). When
    ``build_cache`` (prefill), the cache pytree has stacked (L, ...) leaves
    plus a ``pos`` scalar.
    """
    x = _embed(params, cfg, tokens, vision_embeds=vision_embeds)
    b, s, _ = x.shape
    positions = positions_for(cfg, b, 0, s)
    mrope_pos = mrope_positions
    if cfg.pos_emb == "mrope" and mrope_pos is None:
        mrope_pos = jnp.broadcast_to(positions[None], (3, b, s))
    enc_out = encode(params, cfg, frames, attn_impl) if cfg.is_encdec else None

    def body(carry, lp):
        x, lb, zl = carry
        x, cache, aux = layer_seq(
            lp, x, cfg, positions=positions, mrope_pos=mrope_pos,
            enc_out=enc_out, build_cache=build_cache, cache_len=cache_len,
            attn_impl=attn_impl, kv_chunk=kv_chunk, chunk_size=chunk_size)
        lb = lb + aux.get("load_balance_loss", 0.0)
        zl = zl + aux.get("router_z_loss", 0.0)
        return (x, lb, zl), cache

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    (x, lb, zl), caches = jax.lax.scan(body, (x, 0.0, 0.0), params["layers"])
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _unembed(params, cfg, x)
    cache = None
    if build_cache:
        cache = dict(caches)
        cache["pos"] = jnp.asarray(s, jnp.int32)
    return logits, cache, {"load_balance_loss": lb, "router_z_loss": zl}


def forward_chunk(params, cfg: ModelConfig, tokens: jax.Array, cache: PyTree,
                  *, attn_impl="chunked", kv_chunk=1024,
                  ) -> Tuple[jax.Array, PyTree]:
    """Continue a partial prefill: extend ``cache`` with ``tokens`` (B, C).

    The chunk occupies absolute positions ``cache["pos"] .. pos+C-1``. Per
    layer the chunk's K/V are written into the cache *first* (one
    ``dynamic_update_slice`` at the traced offset), then the chunk's queries
    attend over the whole cache lane with the causal mask keyed on absolute
    positions — rows at positions ≤ the query are exactly the real prefix
    (earlier chunks plus this one), rows beyond are masked out. This makes
    chunked prefill mathematically identical to a single full-prompt
    ``forward_seq`` for attention-family models.

    Supported families: DENSE / MOE / VLM (pure-attention token mixing).
    Recurrent families (SSM / HYBRID) and encoder-decoder models carry
    cross-chunk state that ``forward_seq`` does not externalize, so chunked
    continuation raises for them — the serving engine rejects the
    combination up front (``RealBackend.attach``).

    Requires an append-buffer cache (no ring wraparound): the caller must
    guarantee ``pos + C <= cache_len``; the serving engine enforces
    ``prompt_len <= cache_len`` when chunking is enabled.

    Returns (logits (B, C, V), new_cache). logits[:, -1] is the next-token
    distribution after the chunk — only meaningful to sample from on the
    final chunk of a prompt.
    """
    if cfg.family not in (DENSE, MOE, VLM) or cfg.is_encdec:
        raise NotImplementedError(
            f"forward_chunk supports attention-family models; {cfg.family}"
            f"{' enc-dec' if cfg.is_encdec else ''} carries recurrent "
            f"cross-chunk state")
    pos0 = cache["pos"]
    x = _embed(params, cfg, tokens, start_pos=pos0)
    b, c, _ = x.shape
    positions = positions_for(cfg, b, pos0, c)
    mrope_pos = (jnp.broadcast_to(positions[None], (3, b, c))
                 if cfg.pos_emb == "mrope" else None)
    layer_caches = {k: v for k, v in cache.items() if k != "pos"}

    def body(x, xs):
        lp, cache_l = xs
        new_cache = dict(cache_l)
        xn = apply_norm(lp["ln1"], x, cfg.norm)
        q, k, v = _qkv(lp["attn"], xn, cfg)
        q, k = _rope_qk(q, k, cfg, positions, mrope_pos)
        # append-buffer write at the chunk offset (pos0 is traced data, so
        # one compiled program serves every offset)
        ck = jax.lax.dynamic_update_slice(cache_l["k"], k, (0, pos0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache_l["v"], v, (0, pos0, 0, 0))
        w = ck.shape[1]
        pos_kv = jnp.broadcast_to(jnp.arange(w, dtype=jnp.int32)[None], (b, w))
        out = attention(q, ck, cv, positions, pos_kv, causal=True,
                        window=cfg.sliding_window, impl=attn_impl,
                        kv_chunk=kv_chunk)
        x = x + (out.reshape(b, c, -1) @ lp["attn"]["wo"]
                 + (lp["attn"]["bo"] if "bo" in lp["attn"] else 0))
        xn2 = apply_norm(lp["ln2"], x, cfg.norm)
        ffn_out, _ = _ffn(lp, xn2, cfg)
        x = x + ffn_out
        new_cache.update(k=ck, v=cv)
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["layers"], layer_caches))
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _unembed(params, cfg, x)
    new_cache = dict(new_caches)
    new_cache["pos"] = pos0 + c
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, cache: PyTree, token: jax.Array,
                ) -> Tuple[jax.Array, PyTree]:
    """One decode step. token: (B,1) int32. Returns (logits (B,V), cache)."""
    pos = cache["pos"]
    x = _embed(params, cfg, token, start_pos=pos)
    layer_caches = {k: v for k, v in cache.items() if k != "pos"}

    def body(x, xs):
        lp, cache_l = xs
        x, new_cache, _ = layer_step(lp, x, cfg, cache_l, pos)
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["layers"], layer_caches))
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = _unembed(params, cfg, x)[:, 0]
    new_cache = dict(new_caches)
    new_cache["pos"] = pos + 1
    return logits, new_cache


# ===========================================================================
# Cache construction (decode entry without prefill — dry-run / fresh session)
# ===========================================================================
def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, pos: int = 0,
               dtype=None) -> PyTree:
    """Allocate an (empty or positioned) decode cache pytree."""
    dt = jnp.dtype(dtype or cfg.dtype)
    L = cfg.num_layers
    c: Dict[str, Any] = {"pos": jnp.asarray(pos, jnp.int32)}
    if cfg.family == SSM:
        c["state"] = jnp.zeros((L, batch, cfg.num_heads, cfg.head_dim,
                                cfg.head_dim), jnp.float32)
        c["tm_prev"] = jnp.zeros((L, batch, cfg.d_model), dt)
        c["cm_prev"] = jnp.zeros((L, batch, cfg.d_model), dt)
        return c
    w = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    c["k"] = jnp.zeros((L, batch, w, cfg.num_kv_heads, cfg.head_dim), dt)
    c["v"] = jnp.zeros((L, batch, w, cfg.num_kv_heads, cfg.head_dim), dt)
    if cfg.family == HYBRID:
        d_inner = cfg.ssm.expand * cfg.d_model
        nh = d_inner // cfg.ssm.head_dim
        c["conv_tail"] = jnp.zeros((L, batch, cfg.ssm.conv_width - 1, d_inner), dt)
        c["ssm_state"] = jnp.zeros((L, batch, nh, cfg.ssm.state_size,
                                    cfg.ssm.head_dim), jnp.float32)
    if cfg.is_encdec:
        c["cross_k"] = jnp.zeros((L, batch, cfg.encoder_seq_len,
                                  cfg.num_kv_heads, cfg.head_dim), dt)
        c["cross_v"] = jnp.zeros((L, batch, cfg.encoder_seq_len,
                                  cfg.num_kv_heads, cfg.head_dim), dt)
    return c
