"""Shared building blocks: norms, initializers, activations, positional codes.

All modules are pure functions over explicit param pytrees. Reductions
(norm statistics, softmax, rope rotation) run in float32 regardless of the
param/activation dtype, per TPU numerics practice.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ACT_SILU, ACT_SQ_RELU, ACT_GELU


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------
def dense_init(key, shape, in_axis_size: Optional[int] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (the MaxText/T5 default)."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, dtype) -> dict:
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def group_norm_heads(x: jax.Array, scale: jax.Array, eps: float = 64e-5) -> jax.Array:
    """Per-head group norm (RWKV output norm). x: (..., H, dh)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------
def activate(h: jax.Array, gate: Optional[jax.Array], kind: str) -> jax.Array:
    if kind == ACT_SILU:
        assert gate is not None, "SwiGLU requires a gate projection"
        return jax.nn.silu(gate) * h
    if kind == ACT_SQ_RELU:
        return jnp.square(jax.nn.relu(h))
    if kind == ACT_GELU:
        return jax.nn.gelu(h)
    raise ValueError(f"unknown activation {kind!r}")


def gated(kind: str) -> bool:
    return kind == ACT_SILU


# ---------------------------------------------------------------------------
# RoPE (standard + multimodal M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies, f32."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs. x: (B, S, H, dh); positions: (B, S) int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                     # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs     # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]                          # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1).astype(x.dtype)


def mrope_sections(head_dim: int) -> tuple:
    """Split of the head_dim//2 frequency pairs into (t, h, w) sections.

    Qwen2-VL uses [16, 24, 24] of 64 pairs; we generalize to (1/4, 3/8, 3/8).
    """
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    return (t, h, half - t - h)


def apply_mrope(x: jax.Array, positions_thw: jax.Array, theta: float) -> jax.Array:
    """Multimodal RoPE. positions_thw: (3, B, S) — temporal/height/width ids.

    Frequency pairs are partitioned into three sections, each rotated by its
    own position stream [arXiv:2409.12191 §2.1].
    """
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                     # (half,)
    secs = mrope_sections(x.shape[-1])
    # Build per-pair position ids (B, S, half) by section.
    parts = []
    off = 0
    for i, n in enumerate(secs):
        parts.append(jnp.broadcast_to(positions_thw[i][..., None],
                                      positions_thw.shape[1:] + (n,)))
        off += n
    pos = jnp.concatenate(parts, axis=-1).astype(jnp.float32)  # (B, S, half)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1).astype(x.dtype)


def positions_for(cfg: ModelConfig, batch: int, start: jax.Array, seq: int) -> jax.Array:
    """Default linear positions (B, S) starting at ``start`` (scalar or (B,))."""
    base = jnp.arange(seq, dtype=jnp.int32)[None, :]
    start = jnp.asarray(start, jnp.int32)
    if start.ndim == 0:
        start = start[None]
    return jnp.broadcast_to(base + start[:, None], (batch, seq))
